//! `artifacts/manifest.json` parsing — the contract between `aot.py` and
//! the Rust runtime.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One input/output tensor description.
#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> anyhow::Result<Self> {
        let shape = j
            .get("shape")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| anyhow::anyhow!("io spec missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow::anyhow!("bad dim")))
            .collect::<Result<Vec<_>, _>>()?;
        let dtype = j
            .get("dtype")
            .and_then(|d| d.as_str())
            .ok_or_else(|| anyhow::anyhow!("io spec missing dtype"))?
            .to_string();
        Ok(Self { shape, dtype })
    }
}

/// One exported artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub meta: Json,
    pub sha256: String,
}

impl ArtifactSpec {
    /// Integer metadata field.
    pub fn meta_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.meta
            .get(key)
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow::anyhow!("artifact {}: missing meta '{key}'", self.name))
    }
}

/// The parsed manifest.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub jax_version: String,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} — run `make artifacts` first ({e})",
                path.display()
            )
        })?;
        let json = Json::parse(&text)?;
        let mut artifacts = BTreeMap::new();
        let arts = json
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'artifacts'"))?;
        for (name, j) in arts {
            let inputs = j
                .get("inputs")
                .and_then(|a| a.as_arr())
                .ok_or_else(|| anyhow::anyhow!("artifact {name}: missing inputs"))?
                .iter()
                .map(IoSpec::from_json)
                .collect::<Result<Vec<_>, _>>()?;
            let outputs = j
                .get("outputs")
                .and_then(|a| a.as_arr())
                .ok_or_else(|| anyhow::anyhow!("artifact {name}: missing outputs"))?
                .iter()
                .map(IoSpec::from_json)
                .collect::<Result<Vec<_>, _>>()?;
            let spec = ArtifactSpec {
                name: name.clone(),
                file: j
                    .get("file")
                    .and_then(|f| f.as_str())
                    .ok_or_else(|| anyhow::anyhow!("artifact {name}: missing file"))?
                    .to_string(),
                inputs,
                outputs,
                meta: j.get("meta").cloned().unwrap_or(Json::Null),
                sha256: j
                    .get("sha256")
                    .and_then(|s| s.as_str())
                    .unwrap_or_default()
                    .to_string(),
            };
            artifacts.insert(name.clone(), spec);
        }
        Ok(Self {
            dir: dir.to_path_buf(),
            artifacts,
            jax_version: json
                .get("jax")
                .and_then(|v| v.as_str())
                .unwrap_or("unknown")
                .to_string(),
        })
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&ArtifactSpec> {
        self.artifacts.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "artifact '{name}' not in manifest (have: {:?})",
                self.artifacts.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Absolute path to an artifact's HLO file.
    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("dqgan_manifest_test");
        write_manifest(
            &dir,
            r#"{"jax":"0.8.2","artifacts":{"toy":{
                "file":"toy.hlo.txt",
                "inputs":[{"shape":[4,2],"dtype":"float32"}],
                "outputs":[{"shape":[4],"dtype":"float32"}],
                "meta":{"dim":8},
                "sha256":"abc"}}}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        let a = m.get("toy").unwrap();
        assert_eq!(a.inputs[0].shape, vec![4, 2]);
        assert_eq!(a.inputs[0].numel(), 8);
        assert_eq!(a.outputs[0].shape, vec![4]);
        assert_eq!(a.meta_usize("dim").unwrap(), 8);
        assert!(m.get("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let err = Manifest::load(Path::new("/nonexistent-dir-xyz")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }
}
