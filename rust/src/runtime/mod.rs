//! XLA/PJRT runtime: loads the AOT artifacts `python/compile/aot.py`
//! produced (HLO **text** + `manifest.json`) and executes them on the
//! PJRT CPU client from the training hot path. Python never runs here.
//!
//! - [`Manifest`] — parsed `artifacts/manifest.json` (shapes, dtypes,
//!   per-artifact metadata like the θ/φ split);
//! - [`Runtime`] — PJRT client + compiled-executable cache (one compile
//!   per artifact per process);
//! - [`XlaGradSource`] — [`crate::grad::GradientSource`] backed by the
//!   `*_grad` artifacts (the production gradient path);
//! - [`XlaSampler`] / [`XlaFeatureNet`] — generator sampling and metric
//!   scoring through the exported graphs;
//! - [`XlaQuantizer`] — the Pallas fused quantize+error-feedback kernel
//!   behind the [`crate::compress::Compressor`] trait.

// The real PJRT client needs the vendored `xla` crate; the default build
// substitutes a stub with the same API that still parses manifests but
// errors on load/execute (ISSUE 1: gate missing deps, don't require them).
#[cfg(feature = "xla")]
#[path = "client_xla.rs"]
mod client;
#[cfg(not(feature = "xla"))]
#[path = "client_stub.rs"]
mod client;
mod grad_source;
mod manifest;
mod quantizer;

pub use client::{Executable, Runtime};
pub use grad_source::{DcganInit, XlaFeatureNet, XlaGradSource, XlaSampler};
pub use manifest::{ArtifactSpec, IoSpec, Manifest};
pub use quantizer::XlaQuantizer;

/// Default artifacts directory (relative to the repo root / CWD).
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// Resolve the artifacts directory: `$DQGAN_ARTIFACTS` overrides the
/// default; the manifest must exist there.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("DQGAN_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from(DEFAULT_ARTIFACTS_DIR))
}
