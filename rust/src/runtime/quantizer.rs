//! The Pallas fused quantize+error-feedback kernel as a
//! [`crate::compress::Compressor`]: the L1 kernel on the real Rust hot
//! path. Semantically identical to [`crate::compress::LinfStochastic`]
//! with the same (levels, block); `benches/bench_quantizers.rs` compares
//! the two and the integration tests assert distributional agreement.

use super::client::Runtime;
use super::client::Executable;
use crate::compress::{Compressor, LinfStochastic};
use crate::util::rng::Pcg32;

/// Compressor backed by the `quantize_ef_<model>` artifact.
pub struct XlaQuantizer {
    exe: Executable,
    /// Native twin (same levels/block) used for the wire codec.
    codec: LinfStochastic,
    padded: usize,
    dim: usize,
}

impl XlaQuantizer {
    pub fn new(rt: &Runtime, artifact: &str) -> anyhow::Result<Self> {
        let exe = rt.load(artifact)?;
        let spec = &exe.spec;
        let levels = spec.meta_usize("levels")? as u32;
        let block = spec.meta_usize("block")?;
        Ok(Self {
            codec: LinfStochastic::new(levels).with_block(block),
            padded: spec.meta_usize("padded_dim")?,
            dim: spec.meta_usize("dim")?,
            exe,
        })
    }

    /// Model dimension the artifact was exported for.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Run the kernel: returns (q, e) truncated to `v.len()`.
    pub fn quantize_ef(
        &self,
        v: &[f32],
        rng: &mut Pcg32,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        anyhow::ensure!(
            v.len() <= self.padded,
            "vector length {} exceeds artifact padding {}",
            v.len(),
            self.padded
        );
        let mut p = vec![0.0f32; self.padded];
        p[..v.len()].copy_from_slice(v);
        let u: Vec<f32> = (0..self.padded).map(|_| rng.uniform()).collect();
        let mut out = self.exe.run_f32(&[&p, &u])?;
        let mut e = out.remove(1);
        let mut q = out.remove(0);
        q.truncate(v.len());
        e.truncate(v.len());
        Ok((q, e))
    }
}

impl Compressor for XlaQuantizer {
    fn name(&self) -> String {
        format!("xla[{}]", self.exe.spec.name)
    }

    fn compress(&self, v: &[f32], out: &mut [f32], rng: &mut Pcg32) {
        let (q, _e) = self.quantize_ef(v, rng).expect("xla quantize_ef failed");
        out.copy_from_slice(&q);
    }

    fn encode(&self, quantized: &[f32], buf: &mut Vec<u8>) {
        self.codec.encode(quantized, buf);
    }

    fn decode(&self, bytes: &[u8], d: usize) -> anyhow::Result<Vec<f32>> {
        self.codec.decode(bytes, d)
    }

    fn delta(&self, d: usize) -> Option<f64> {
        self.codec.delta(d)
    }

    fn encoded_size(&self, d: usize) -> usize {
        self.codec.encoded_size(d)
    }
}
