//! Stub PJRT client used when the `xla` feature is off (the default).
//!
//! The offline build image does not ship the vendored `xla` crate, so the
//! real client (`client_xla.rs`) cannot compile there. This stub keeps the
//! whole `runtime` API surface (and everything downstream of it — the CLI
//! `info` command, the figure harnesses, the artifact integration tests)
//! compiling and linking. Manifest parsing still works; anything that
//! would actually execute an XLA artifact returns a descriptive error.
//!
//! The artifact-dependent tests and benches all check for
//! `artifacts/manifest.json` before touching the runtime, so a default
//! build skips them rather than failing.

use super::manifest::{ArtifactSpec, Manifest};
use crate::util::timer::PhaseProfiler;
use std::path::Path;
use std::sync::Arc;

/// Manifest + profiler without a PJRT client. Cheap to clone; safe to
/// share across worker threads (same contract as the real client).
#[derive(Clone)]
pub struct Runtime {
    manifest: Arc<Manifest>,
    profiler: Arc<PhaseProfiler>,
}

/// A handle to one artifact's spec. Never constructed by the stub (load
/// fails first), but the type must exist for downstream code.
#[derive(Clone)]
pub struct Executable {
    pub spec: ArtifactSpec,
}

fn xla_unavailable(what: &str) -> anyhow::Error {
    anyhow::anyhow!(
        "cannot {what}: this binary was built without the `xla` feature \
         (the PJRT/XLA runtime). Enabling it takes two steps — vendor the \
         xla crate and add it under [dependencies] in rust/Cargo.toml \
         (see the [features] comment there), then build with \
         `--features xla` — or use the native models instead \
         (`--model mlp --native`, QuadraticOperator, BilinearGame)."
    )
}

impl Runtime {
    /// Create against an artifacts directory. Manifest parsing works
    /// without XLA; execution does not.
    pub fn new(dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(dir)?;
        crate::log_warn!(
            "XLA runtime stub: manifest parsed ({} artifacts) but execution \
             is unavailable without the `xla` feature",
            manifest.artifacts.len()
        );
        Ok(Self { manifest: Arc::new(manifest), profiler: Arc::new(PhaseProfiler::new()) })
    }

    /// Default location (`artifacts/` or `$DQGAN_ARTIFACTS`).
    pub fn from_default_dir() -> anyhow::Result<Self> {
        Self::new(&super::artifacts_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile/execute phase profiler (always empty in the stub).
    pub fn profiler(&self) -> &PhaseProfiler {
        &self.profiler
    }

    /// Always errors: compiling an artifact needs the real PJRT client.
    pub fn load(&self, name: &str) -> anyhow::Result<Executable> {
        // Validate the name so callers still get manifest-level errors.
        let _ = self.manifest.get(name)?;
        Err(xla_unavailable(&format!("compile artifact '{name}'")))
    }

    /// Load + run in one call (always errors in the stub).
    pub fn run(&self, name: &str, _inputs: &[&[f32]]) -> anyhow::Result<Vec<Vec<f32>>> {
        Err(self.load(name).err().unwrap_or_else(|| xla_unavailable("execute")))
    }
}

impl Executable {
    /// Execute with f32 buffers (always errors in the stub).
    pub fn run_f32(&self, _inputs: &[&[f32]]) -> anyhow::Result<Vec<Vec<f32>>> {
        Err(xla_unavailable(&format!("execute artifact '{}'", self.spec.name)))
    }
}
