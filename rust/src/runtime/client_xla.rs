//! PJRT client wrapper + compiled-executable cache.
//!
//! Pattern from /opt/xla-example/src/bin/load_hlo.rs:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`.
//! Artifacts are lowered with `return_tuple=True`, so every execution
//! returns one tuple literal we decompose.
//!
//! ## Threading
//!
//! The `xla` crate's handles are `!Send` (`Rc` refcounts + raw pointers),
//! but the PS runtime runs gradient evaluation on M worker threads. We
//! therefore confine *every* XLA object inside [`Core`] behind one
//! `Mutex`, and the public API only moves plain `Vec<f32>` across the
//! boundary.
//!
//! SAFETY argument for the `unsafe impl Send for Core`:
//! - all `Rc` clone/drop and all raw-pointer use happen while holding the
//!   mutex, so refcount updates are serialized;
//! - the final drop of the `Core` is serialized by the owning `Arc`;
//! - the PJRT CPU client itself is documented thread-safe, and no XLA
//!   handle ever escapes the mutex (literals are converted to `Vec<f32>`
//!   before returning).
//!
//! Execution is serialized by the mutex; on this single-core testbed the
//! M workers' XLA calls would serialize on the CPU anyway (§Perf measures
//! the mutex's overhead as part of the `execute` phase).

use super::manifest::{ArtifactSpec, Manifest};
use crate::util::timer::PhaseProfiler;
use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

struct Core {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

// SAFETY: see module docs — all access is serialized by the Mutex below
// and no XLA handle crosses the API boundary.
unsafe impl Send for Core {}

/// PJRT CPU client + manifest + executable cache. Cheap to clone; safe to
/// share across worker threads.
#[derive(Clone)]
pub struct Runtime {
    core: Arc<Mutex<Core>>,
    manifest: Arc<Manifest>,
    profiler: Arc<PhaseProfiler>,
}

/// A lightweight handle to one compiled artifact: the artifact's spec plus
/// the shared runtime. `run_f32` executes it.
#[derive(Clone)]
pub struct Executable {
    pub spec: ArtifactSpec,
    rt: Runtime,
}

impl Runtime {
    /// Create against an artifacts directory (compiles lazily).
    pub fn new(dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        crate::log_info!(
            "PJRT client up: platform={} devices={} manifest={} artifacts (jax {})",
            client.platform_name(),
            client.device_count(),
            manifest.artifacts.len(),
            manifest.jax_version
        );
        Ok(Self {
            core: Arc::new(Mutex::new(Core { client, cache: HashMap::new() })),
            manifest: Arc::new(manifest),
            profiler: Arc::new(PhaseProfiler::new()),
        })
    }

    /// Default location (`artifacts/` or `$DQGAN_ARTIFACTS`).
    pub fn from_default_dir() -> anyhow::Result<Self> {
        Self::new(&super::artifacts_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile/execute phase profiler.
    pub fn profiler(&self) -> &PhaseProfiler {
        &self.profiler
    }

    /// Ensure an artifact is compiled; returns its handle.
    pub fn load(&self, name: &str) -> anyhow::Result<Executable> {
        let spec = self.manifest.get(name)?.clone();
        {
            let core = self.core.lock().unwrap();
            if core.cache.contains_key(name) {
                return Ok(Executable { spec, rt: self.clone() });
            }
        }
        let path = self.manifest.path_of(&spec);
        let path_str =
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path {path:?}"))?;
        self.profiler.time("compile", || -> anyhow::Result<()> {
            let mut core = self.core.lock().unwrap();
            if core.cache.contains_key(name) {
                return Ok(()); // raced with another thread
            }
            let proto = xla::HloModuleProto::from_text_file(path_str)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = core.client.compile(&comp)?;
            core.cache.insert(name.to_string(), exe);
            Ok(())
        })?;
        crate::log_info!("compiled artifact '{name}' from {}", path.display());
        Ok(Executable { spec, rt: self.clone() })
    }

    /// Load + run in one call.
    pub fn run(&self, name: &str, inputs: &[&[f32]]) -> anyhow::Result<Vec<Vec<f32>>> {
        self.load(name)?.run_f32(inputs)
    }

    fn execute(&self, spec: &ArtifactSpec, inputs: &[&[f32]]) -> anyhow::Result<Vec<Vec<f32>>> {
        anyhow::ensure!(
            inputs.len() == spec.inputs.len(),
            "{}: expected {} inputs, got {}",
            spec.name,
            spec.inputs.len(),
            inputs.len()
        );
        for (buf, io) in inputs.iter().zip(&spec.inputs) {
            anyhow::ensure!(
                buf.len() == io.numel(),
                "{}: input length {} ≠ shape {:?}",
                spec.name,
                buf.len(),
                io.shape
            );
        }
        self.profiler.time("execute", || {
            let core = self.core.lock().unwrap();
            let exe = core
                .cache
                .get(&spec.name)
                .ok_or_else(|| anyhow::anyhow!("artifact '{}' not compiled", spec.name))?;
            // Build literals inside the lock (literals hold raw pointers).
            let mut literals = Vec::with_capacity(inputs.len());
            for (buf, io) in inputs.iter().zip(&spec.inputs) {
                let lit = xla::Literal::vec1(buf);
                let lit = if io.shape.len() == 1 {
                    lit
                } else {
                    // rank 0 (scalars like eta) and rank ≥ 2 both reshape.
                    let dims: Vec<i64> = io.shape.iter().map(|&d| d as i64).collect();
                    lit.reshape(&dims)?
                };
                literals.push(lit);
            }
            let result = exe.execute::<xla::Literal>(&literals)?;
            let tuple = result[0][0].to_literal_sync()?;
            let parts = tuple.to_tuple()?;
            anyhow::ensure!(
                parts.len() == spec.outputs.len(),
                "{}: expected {} outputs, got {}",
                spec.name,
                spec.outputs.len(),
                parts.len()
            );
            let mut out = Vec::with_capacity(parts.len());
            for (lit, io) in parts.into_iter().zip(&spec.outputs) {
                let v = lit.to_vec::<f32>()?;
                anyhow::ensure!(
                    v.len() == io.numel(),
                    "{}: output length {} ≠ shape {:?}",
                    spec.name,
                    v.len(),
                    io.shape
                );
                out.push(v);
            }
            Ok(out)
        })
    }
}

impl Executable {
    /// Execute with f32 buffers (one per manifest input, row-major).
    /// Returns one Vec<f32> per manifest output.
    pub fn run_f32(&self, inputs: &[&[f32]]) -> anyhow::Result<Vec<Vec<f32>>> {
        self.rt.execute(&self.spec, inputs)
    }
}
