//! Observability: process-global metrics registry + span tracing
//! (ADR-004).
//!
//! Two halves, both compiled in and both near-free when disabled:
//!
//! * [`registry`] — counters, gauges (with high-water marks) and
//!   fixed-bucket log2 histograms, all `AtomicU64` statics declared
//!   centrally in [`metrics`]. Dumped at run end by `--metrics-json`
//!   as a schema-versioned document ([`SCHEMA`]).
//! * [`trace`] — RAII spans emitting Chrome/Perfetto trace-event JSON
//!   (`--trace`), lanes: leader round engine on tid 0, in-process
//!   worker `i` on tid 1+i.
//!
//! This module also owns the cross-cutting state neither half fits:
//! the broadcast-send timestamps the leader's ack RTT metric is
//! computed from, and the per-(worker, round) row table behind
//! `--worker-csv`.
//!
//! Everything here records **counts and clock durations only** — no
//! training numerics are read or written, so flipping any obs flag
//! cannot change a broadcast bit (CI diffs `broadcast_fnv` between
//! obs-on and obs-off runs to enforce exactly that).

pub mod registry;
pub mod trace;

pub use registry::{enable_metrics, metrics_enabled};
pub use trace::{enable_trace, span, trace_enabled, worker_tid, LEADER_TID};

use crate::comm::ByteCounter;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Version tag of the `--metrics-json` document; bump on any breaking
/// reshape of the dump layout.
pub const SCHEMA: &str = "dqgan.metrics.v1";

/// Every process-global metric, declared in one place so the dump (and
/// the `metrics-check` required-keys gate) enumerates the complete set
/// — a metric whose code path never ran still appears as zeros. Use
/// sites are one line: `obs::metrics::NAME.inc()` / `.set(v)` /
/// `.record(v)`.
pub mod metrics {
    crate::obs::registry::obs_metrics! {
        counters {
            EVLOOP_POLL_ITERATIONS => "evloop.poll_iterations",
            EVLOOP_WAKEUPS => "evloop.wakeups",
            EVLOOP_PARTIAL_WRITES_RESUMED => "evloop.partial_writes_resumed",
            EVLOOP_DELIVERIES => "evloop.deliveries",
            AGG_CLOSE_INLINE => "agg.close_inline",
            AGG_CLOSE_OFFLOADED => "agg.close_offloaded",
            AGG_FOLD_POOL_DISPATCH => "agg.fold_pool_dispatch",
            AGG_FOLD_CALLER_INLINE => "agg.fold_caller_inline",
            WORKER_ABSORBED_SKIPS => "worker.absorbed_skips",
            TRANSPORT_BYTES_UP => "transport.bytes_up",
            TRANSPORT_BYTES_DOWN => "transport.bytes_down",
            TRANSPORT_BYTES_CTRL => "transport.bytes_ctrl",
            CODEC_BYTES_PRE_TOTAL => "codec.bytes_pre_total",
            CODEC_BYTES_POST_TOTAL => "codec.bytes_post_total",
            RECOVERY_EVICTIONS => "recovery.evictions",
            RECOVERY_REJOINS => "recovery.rejoins",
            RECOVERY_REPLAYED_FRAMES => "recovery.replayed_frames",
            RECOVERY_CKPT_BYTES => "recovery.ckpt_bytes",
            RECOVERY_CKPT_READ_BYTES => "recovery.ckpt_read_bytes",
            RECOVERY_RECONNECT_ATTEMPTS => "recovery.reconnect_attempts",
            RECOVERY_BACKOFF_SLEEPS => "recovery.backoff_sleeps",
        }
        gauges {
            EVLOOP_OUTRING_DEPTH => "evloop.outring_depth",
            EVLOOP_PARKED_FRAMES => "evloop.parked_frames",
            ACK_INFLIGHT => "ack.inflight",
        }
        histograms {
            EVLOOP_IDLE_WAIT_NS => "evloop.idle_wait_ns",
            CODEC_ENCODE_NS => "codec.encode_ns",
            CODEC_DECODE_NS => "codec.decode_ns",
            CODEC_BYTES_WIRE => "codec.bytes_wire",
            WORKER_APPLY_NS => "worker.apply_ns",
            WORKER_ACK_RTT_NS => "worker.ack_rtt_ns",
            AGG_FOLD_BATCH_ELEMS => "agg.fold_batch_elems",
        }
    }
}

// ----------------------------------------------------- timing helpers ----

/// Gated clock read: `None` (no syscall, single relaxed load) while
/// metrics are disabled. Pair with [`record_elapsed`].
#[inline]
pub fn maybe_now() -> Option<Instant> {
    if metrics_enabled() {
        Some(Instant::now())
    } else {
        None
    }
}

/// Record `t0.elapsed()` in nanoseconds into `h` when `t0` was taken
/// (i.e. metrics were on at [`maybe_now`] time).
#[inline]
pub fn record_elapsed(h: &registry::Histogram, t0: Option<Instant>) {
    if let Some(t0) = t0 {
        h.record(t0.elapsed().as_nanos() as u64);
    }
}

// ------------------------------------------- per-(worker, round) rows ----

static WORKER_ROWS_ON: AtomicBool = AtomicBool::new(false);

/// Whether `--worker-csv` row collection is on.
#[inline]
pub fn worker_rows_enabled() -> bool {
    WORKER_ROWS_ON.load(Ordering::Relaxed)
}

/// Turn on per-(worker, round) row collection. Rows need the apply/ack
/// clocks, so this implies [`enable_metrics`].
pub fn enable_worker_rows() {
    enable_metrics();
    WORKER_ROWS_ON.store(true, Ordering::Relaxed);
}

#[derive(Default, Clone)]
struct WorkerRow {
    apply_ns: Option<u64>,
    ack_rtt_ns: Option<u64>,
    absorbed_skip: bool,
    err_norm: Option<f64>,
}

/// Rows keyed (round, worker) so the CSV comes out round-major.
static WORKER_ROWS: Mutex<BTreeMap<(u64, usize), WorkerRow>> = Mutex::new(BTreeMap::new());

fn with_row(worker: usize, round: u64, f: impl FnOnce(&mut WorkerRow)) {
    let mut rows = WORKER_ROWS.lock().expect("worker rows lock");
    f(rows.entry((round, worker)).or_default());
}

// -------------------------------------------------- leader-side hooks ----

/// Broadcast-send timestamps the ack RTT is measured against, most
/// recent last. Bounded: the ledger caps rounds in flight far below
/// this, so trimming the front never drops a round still awaiting acks.
static BROADCAST_SENDS: Mutex<Vec<(u64, Instant)>> = Mutex::new(Vec::new());
const BROADCAST_SENDS_CAP: usize = 1024;

/// Leader hook: round `round`'s broadcast was handed to the transport
/// now. The subsequent per-worker [`note_ack`] calls compute their RTT
/// against this instant.
pub fn note_broadcast_sent(round: u64) {
    if !metrics_enabled() {
        return;
    }
    let mut sends = BROADCAST_SENDS.lock().expect("broadcast sends lock");
    sends.push((round, Instant::now()));
    if sends.len() > BROADCAST_SENDS_CAP {
        let excess = sends.len() - BROADCAST_SENDS_CAP;
        sends.drain(..excess);
    }
}

/// Leader hook: worker `worker` acked round `round` (seen at the
/// leader's `AckLedger`). Records the send→ack RTT histogram and the
/// worker row's ack column.
pub fn note_ack(worker: usize, round: u64) {
    if !metrics_enabled() {
        return;
    }
    let sent = {
        let sends = BROADCAST_SENDS.lock().expect("broadcast sends lock");
        sends.iter().rev().find(|(r, _)| *r == round).map(|(_, t)| *t)
    };
    let Some(sent) = sent else {
        return; // broadcast predates enable, or was trimmed
    };
    let rtt_ns = sent.elapsed().as_nanos() as u64;
    metrics::WORKER_ACK_RTT_NS.record(rtt_ns);
    if worker_rows_enabled() {
        with_row(worker, round, |row| row.ack_rtt_ns = Some(rtt_ns));
    }
}

// -------------------------------------------------- worker-side hooks ----

/// Worker hook: produce() for `round` finished with error memory of
/// squared L2 norm `err_norm_sq`.
pub fn worker_produce(worker: usize, round: u64, err_norm_sq: f32) {
    if worker_rows_enabled() {
        with_row(worker, round, |row| row.err_norm = Some((err_norm_sq as f64).sqrt()));
    }
}

/// Worker hook: a broadcast for `round` was applied in `apply_ns`
/// nanoseconds; `absorbed` marks the policy-skipped path (payload
/// folded back into error memory, e ← e + q̂).
pub fn worker_apply(worker: usize, round: u64, apply_ns: u64, absorbed: bool) {
    metrics::WORKER_APPLY_NS.record(apply_ns);
    if absorbed {
        metrics::WORKER_ABSORBED_SKIPS.inc();
    }
    if worker_rows_enabled() {
        with_row(worker, round, |row| {
            row.apply_ns = Some(apply_ns);
            row.absorbed_skip = absorbed;
        });
    }
}

// ----------------------------------------------------- run-end sinks ----

/// Fold a transport's final [`ByteCounter`] totals into the unified
/// `transport.bytes_*` counters (called once per run, at teardown).
pub fn record_transport_totals(counter: &ByteCounter) {
    metrics::TRANSPORT_BYTES_UP.add(counter.up_total());
    metrics::TRANSPORT_BYTES_DOWN.add(counter.down_total());
    metrics::TRANSPORT_BYTES_CTRL.add(counter.ctrl_total());
}

/// Render the full registry dump (every declared metric, zeros
/// included) with `meta` under a `"run"` key.
pub fn metrics_json(meta: BTreeMap<String, Json>) -> Json {
    registry::registry_json(
        SCHEMA,
        meta,
        metrics::all_counters(),
        metrics::all_gauges(),
        metrics::all_histograms(),
    )
}

/// Write the metrics dump to `path` (creating parent directories).
pub fn write_metrics_json(path: &Path, meta: BTreeMap<String, Json>) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, metrics_json(meta).to_string_compact() + "\n")?;
    Ok(())
}

/// Validate a parsed metrics dump: schema tag, section presence, and
/// one required key per **declared** metric — driven off the same
/// central declaration the dump is, so the check can never drift from
/// the registry. Shared by `dqgan metrics-check` and the obs
/// integration test.
pub fn check_metrics_json(doc: &Json) -> anyhow::Result<()> {
    let schema = doc
        .get("schema")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("metrics dump: missing schema tag"))?;
    anyhow::ensure!(schema == SCHEMA, "metrics dump: schema {schema:?}, expected {SCHEMA:?}");
    anyhow::ensure!(doc.get("run").and_then(Json::as_obj).is_some(), "missing run section");
    let counters = doc
        .get("counters")
        .and_then(Json::as_obj)
        .ok_or_else(|| anyhow::anyhow!("metrics dump: missing counters section"))?;
    for c in metrics::all_counters() {
        anyhow::ensure!(
            counters.get(c.name()).and_then(Json::as_f64).is_some(),
            "metrics dump: missing counter {:?}",
            c.name()
        );
    }
    let gauges = doc
        .get("gauges")
        .and_then(Json::as_obj)
        .ok_or_else(|| anyhow::anyhow!("metrics dump: missing gauges section"))?;
    for g in metrics::all_gauges() {
        let entry = gauges
            .get(g.name())
            .ok_or_else(|| anyhow::anyhow!("metrics dump: missing gauge {:?}", g.name()))?;
        anyhow::ensure!(
            entry.get("value").and_then(Json::as_f64).is_some()
                && entry.get("hwm").and_then(Json::as_f64).is_some(),
            "metrics dump: gauge {:?} missing value/hwm",
            g.name()
        );
    }
    let hists = doc
        .get("histograms")
        .and_then(Json::as_obj)
        .ok_or_else(|| anyhow::anyhow!("metrics dump: missing histograms section"))?;
    for h in metrics::all_histograms() {
        let entry = hists
            .get(h.name())
            .ok_or_else(|| anyhow::anyhow!("metrics dump: missing histogram {:?}", h.name()))?;
        anyhow::ensure!(
            entry.get("count").and_then(Json::as_f64).is_some()
                && entry.get("sum").and_then(Json::as_f64).is_some()
                && entry.get("buckets").and_then(Json::as_obj).is_some(),
            "metrics dump: histogram {:?} missing count/sum/buckets",
            h.name()
        );
    }
    Ok(())
}

/// Column order of the `--worker-csv` sink: one row per
/// (worker, round), empty cells where a quantity was never observed
/// (e.g. no ack RTT under `--transport threads` with acks off).
pub const WORKER_CSV_HEADER: [&str; 6] =
    ["worker", "round", "apply_ns", "ack_rtt_ns", "absorbed_skip", "err_norm"];

/// Write the per-(worker, round) rows collected so far to `path`
/// (round-major order) and return the written path.
pub fn write_worker_csv(path: &Path) -> anyhow::Result<String> {
    let rows = WORKER_ROWS.lock().expect("worker rows lock").clone();
    let mut csv = crate::telemetry::CsvWriter::create(path, &WORKER_CSV_HEADER)?;
    let opt_u64 = |v: Option<u64>| v.map(|n| n.to_string()).unwrap_or_default();
    for ((round, worker), row) in &rows {
        csv.row(&[
            worker.to_string(),
            round.to_string(),
            opt_u64(row.apply_ns),
            opt_u64(row.ack_rtt_ns),
            if row.absorbed_skip { "1".to_string() } else { "0".to_string() },
            row.err_norm.map(|n| format!("{n:.6e}")).unwrap_or_default(),
        ])?;
    }
    csv.finish()
}

/// Write the collected trace spans to `path` as Chrome trace-event
/// JSON (creating parent directories). Drains the span buffer.
pub fn write_trace(path: &Path) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, trace::trace_json().to_string_compact() + "\n")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_dump_passes_its_own_check() {
        enable_metrics();
        let mut meta = BTreeMap::new();
        meta.insert("workers".to_string(), Json::Num(4.0));
        let doc = metrics_json(meta);
        let back = Json::parse(&doc.to_string_compact()).unwrap();
        check_metrics_json(&back).unwrap();
    }

    #[test]
    fn check_rejects_missing_required_keys() {
        enable_metrics();
        let doc = metrics_json(BTreeMap::new());
        let text = doc.to_string_compact();
        // Drop one required counter and the check must name it.
        let mangled = text.replace("\"evloop.deliveries\"", "\"evloop.deliveries_gone\"");
        let back = Json::parse(&mangled).unwrap();
        let err = check_metrics_json(&back).unwrap_err().to_string();
        assert!(err.contains("evloop.deliveries"), "error names the missing key: {err}");
        // Wrong schema tag is rejected up front.
        let wrong = text.replace(SCHEMA, "dqgan.metrics.v0");
        let back = Json::parse(&wrong).unwrap();
        assert!(check_metrics_json(&back).is_err());
    }

    #[test]
    fn worker_rows_capture_apply_ack_and_absorb() {
        enable_worker_rows();
        assert!(metrics_enabled(), "worker rows imply metrics");
        // Use a round number no real run in this test binary reaches.
        let round = 900_000_071;
        note_broadcast_sent(round);
        worker_produce(3, round, 4.0);
        worker_apply(3, round, 1234, true);
        note_ack(3, round);
        let path = std::env::temp_dir().join("dqgan_worker_csv_test.csv");
        let p = write_worker_csv(&path).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let line = text
            .lines()
            .find(|l| l.starts_with(&format!("3,{round},")))
            .expect("row for (worker 3, test round)");
        let cells: Vec<&str> = line.split(',').collect();
        assert_eq!(cells.len(), WORKER_CSV_HEADER.len());
        assert_eq!(cells[2], "1234", "apply_ns recorded");
        assert!(!cells[3].is_empty(), "ack RTT recorded");
        assert_eq!(cells[4], "1", "absorbed skip flagged");
        assert_eq!(cells[5], "2.000000e0", "err L2 norm = sqrt(4)");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn ack_without_matching_broadcast_is_ignored() {
        enable_worker_rows();
        note_ack(17, 900_000_999); // round was never broadcast
        let rows = WORKER_ROWS.lock().unwrap();
        assert!(
            !rows.contains_key(&(900_000_999, 17)),
            "unmatched ack must not fabricate a worker row"
        );
    }
}
