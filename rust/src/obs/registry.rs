//! Zero-dependency metrics registry: process-global counters, gauges
//! (with high-water marks) and fixed-bucket log2 histograms, all plain
//! `AtomicU64` state so the hot path is lock-free.
//!
//! ## Hot-path contract (ADR-004)
//!
//! Every record method starts with a **single relaxed load** of the
//! process-global [`metrics_enabled`] flag and returns immediately when
//! observability is off — no `Instant::now()`, no registry lookup, no
//! fence. Callers that need a timestamp pair use
//! [`crate::obs::maybe_now`] so the clock read itself is gated too.
//! When enabled, a record is one or a few relaxed `fetch_add`s on
//! statics: no locks, no allocation, safe from any thread (pool
//! workers, transport loops, in-process worker threads).
//!
//! ## Registration
//!
//! Metrics are `static` items declared centrally through the
//! [`obs_metrics!`] macro (one line per metric — see
//! `crate::obs::metrics`), which also generates the complete
//! enumeration the JSON dump walks. Central declaration is what makes
//! the dump *total*: a metric whose code path never ran still appears
//! (as zeros), so the CI `metrics-check` schema gate can assert key
//! presence without depending on which branches a run exercised.
//!
//! ## Determinism
//!
//! Nothing here touches training numerics: the registry records counts
//! and clock durations only, so enabling metrics cannot move a single
//! bit of any broadcast (the CI obs-on/obs-off `broadcast_fnv` diff
//! enforces this end to end).

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Process-global metrics switch. Off by default; flipped once by
/// [`enable_metrics`] (never back — tests and sinks rely on
/// monotonicity within a process).
static ENABLED: AtomicBool = AtomicBool::new(false);

/// The one relaxed load every hot-path record gates on.
#[inline(always)]
pub fn metrics_enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn metrics recording on for the rest of the process lifetime.
pub fn enable_metrics() {
    ENABLED.store(true, Ordering::Relaxed);
}

/// Monotonic event counter.
pub struct Counter {
    name: &'static str,
    cell: AtomicU64,
}

impl Counter {
    pub const fn new(name: &'static str) -> Self {
        Self { name, cell: AtomicU64::new(0) }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Add `n` (no-op while metrics are disabled).
    #[inline]
    pub fn add(&self, n: u64) {
        if !metrics_enabled() {
            return;
        }
        self.cell.fetch_add(n, Ordering::Relaxed);
    }

    /// Add 1 (no-op while metrics are disabled).
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.cell.load(Ordering::Relaxed)
    }
}

/// Last-value gauge with a monotone high-water mark.
pub struct Gauge {
    name: &'static str,
    value: AtomicU64,
    hwm: AtomicU64,
}

impl Gauge {
    pub const fn new(name: &'static str) -> Self {
        Self { name, value: AtomicU64::new(0), hwm: AtomicU64::new(0) }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Record the current level; the high-water mark keeps the max ever
    /// seen (no-op while metrics are disabled).
    #[inline]
    pub fn set(&self, v: u64) {
        if !metrics_enabled() {
            return;
        }
        self.value.store(v, Ordering::Relaxed);
        self.hwm.fetch_max(v, Ordering::Relaxed);
    }

    pub fn value(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }

    pub fn hwm(&self) -> u64 {
        self.hwm.load(Ordering::Relaxed)
    }
}

/// Number of histogram buckets: bucket 0 holds exact zeros, bucket b
/// (1 ≤ b ≤ 64) holds values v with `64 − v.leading_zeros() == b`,
/// i.e. v ∈ [2^(b−1), 2^b − 1]. `u64::MAX` lands in bucket 64.
pub const HIST_BUCKETS: usize = 65;

/// Map a value to its log2 bucket index (see [`HIST_BUCKETS`]).
#[inline]
pub fn log2_bucket(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        (64 - v.leading_zeros()) as usize
    }
}

/// Fixed-bucket log2 histogram for latencies (ns) and sizes (bytes):
/// 65 relaxed `AtomicU64` buckets plus running count and sum, so mean
/// and order-of-magnitude distribution are both recoverable from the
/// dump without any per-record allocation.
pub struct Histogram {
    name: &'static str,
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Histogram {
    pub const fn new(name: &'static str) -> Self {
        // Const-item trick: a `const` with interior mutability is the
        // sanctioned way to array-initialize atomics.
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Self {
            name,
            buckets: [ZERO; HIST_BUCKETS],
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Record one observation (no-op while metrics are disabled).
    #[inline]
    pub fn record(&self, v: u64) {
        if !metrics_enabled() {
            return;
        }
        self.buckets[log2_bucket(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn bucket(&self, i: usize) -> u64 {
        self.buckets[i].load(Ordering::Relaxed)
    }
}

/// Declare the process-global metric statics **and** the total
/// enumeration the dump walks, in one place. Adding a metric is one
/// line inside the block; the use site is then
/// `obs::metrics::NAME.inc()` (or `.set`/`.record`) — also one line.
macro_rules! obs_metrics {
    (
        counters { $($cname:ident => $ckey:literal,)* }
        gauges { $($gname:ident => $gkey:literal,)* }
        histograms { $($hname:ident => $hkey:literal,)* }
    ) => {
        $(pub static $cname: $crate::obs::registry::Counter =
            $crate::obs::registry::Counter::new($ckey);)*
        $(pub static $gname: $crate::obs::registry::Gauge =
            $crate::obs::registry::Gauge::new($gkey);)*
        $(pub static $hname: $crate::obs::registry::Histogram =
            $crate::obs::registry::Histogram::new($hkey);)*

        /// Every declared counter (declaration order).
        pub fn all_counters() -> &'static [&'static $crate::obs::registry::Counter] {
            &[$(&$cname),*]
        }
        /// Every declared gauge (declaration order).
        pub fn all_gauges() -> &'static [&'static $crate::obs::registry::Gauge] {
            &[$(&$gname),*]
        }
        /// Every declared histogram (declaration order).
        pub fn all_histograms() -> &'static [&'static $crate::obs::registry::Histogram] {
            &[$(&$hname),*]
        }
    };
}
pub(crate) use obs_metrics;

/// Serialize one histogram as `{count, sum, buckets: {"<idx>": n, …}}`
/// (only non-empty buckets are emitted — the dump stays readable at 65
/// buckets per histogram).
fn histogram_json(h: &Histogram) -> Json {
    let mut buckets = BTreeMap::new();
    for i in 0..HIST_BUCKETS {
        let n = h.bucket(i);
        if n > 0 {
            buckets.insert(format!("{i:02}"), Json::Num(n as f64));
        }
    }
    let mut obj = BTreeMap::new();
    obj.insert("count".to_string(), Json::Num(h.count() as f64));
    obj.insert("sum".to_string(), Json::Num(h.sum() as f64));
    obj.insert("buckets".to_string(), Json::Obj(buckets));
    Json::Obj(obj)
}

/// Render the full registry (every declared metric, zeros included) as
/// the schema-versioned dump object. `meta` rides along under a "run"
/// key so the dump is self-describing.
pub fn registry_json(
    schema: &str,
    meta: BTreeMap<String, Json>,
    counters: &[&'static Counter],
    gauges: &[&'static Gauge],
    histograms: &[&'static Histogram],
) -> Json {
    let mut c = BTreeMap::new();
    for m in counters {
        c.insert(m.name().to_string(), Json::Num(m.get() as f64));
    }
    let mut g = BTreeMap::new();
    for m in gauges {
        let mut obj = BTreeMap::new();
        obj.insert("value".to_string(), Json::Num(m.value() as f64));
        obj.insert("hwm".to_string(), Json::Num(m.hwm() as f64));
        g.insert(m.name().to_string(), Json::Obj(obj));
    }
    let mut h = BTreeMap::new();
    for m in histograms {
        h.insert(m.name().to_string(), histogram_json(m));
    }
    let mut root = BTreeMap::new();
    root.insert("schema".to_string(), Json::Str(schema.to_string()));
    root.insert("run".to_string(), Json::Obj(meta));
    root.insert("counters".to_string(), Json::Obj(c));
    root.insert("gauges".to_string(), Json::Obj(g));
    root.insert("histograms".to_string(), Json::Obj(h));
    Json::Obj(root)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Dedicated test statics: unit tests share one process, so these
    // must not be metrics any production path records into, and all
    // assertions are on values only this test drives.
    static T_COUNT: Counter = Counter::new("test.registry.count");
    static T_GAUGE: Gauge = Gauge::new("test.registry.gauge");
    static T_HIST: Histogram = Histogram::new("test.registry.hist");
    static T_OFF: Counter = Counter::new("test.registry.off");

    #[test]
    fn log2_bucket_boundary_edge_cases() {
        assert_eq!(log2_bucket(0), 0, "exact zero has its own bucket");
        assert_eq!(log2_bucket(1), 1);
        assert_eq!(log2_bucket(2), 2);
        assert_eq!(log2_bucket(3), 2);
        assert_eq!(log2_bucket(4), 3);
        assert_eq!(log2_bucket((1 << 10) - 1), 10);
        assert_eq!(log2_bucket(1 << 10), 11);
        assert_eq!(log2_bucket(u64::MAX), 64, "top bucket holds u64::MAX");
        assert_eq!(log2_bucket(1 << 63), 64);
        assert_eq!(log2_bucket((1 << 63) - 1), 63);
    }

    #[test]
    fn disabled_recording_is_a_no_op() {
        // The enable flag is process-global and other tests in this
        // binary flip it concurrently, so the disabled-path assertion
        // must tolerate a racing enable: if the add recorded anything,
        // the flag must have been flipped between our check and the
        // add; if the flag stayed off, nothing may be recorded.
        if !metrics_enabled() {
            T_OFF.add(7);
            let v = T_OFF.get();
            assert!(
                v == 0 || metrics_enabled(),
                "disabled add recorded {v} with the flag still off"
            );
        }
        enable_metrics();
        let before = T_OFF.get();
        T_OFF.add(5);
        assert_eq!(T_OFF.get(), before + 5);
    }

    #[test]
    fn concurrent_increments_under_the_thread_pool_lose_nothing() {
        enable_metrics();
        let c0 = T_COUNT.get();
        let h0 = T_HIST.count();
        let pool = crate::util::threadpool::ThreadPool::new(4);
        let mut units: Vec<u64> = (0..64u64).collect();
        pool.parallel_for_mut(&mut units, |_, seed| {
            for k in 0..1000u64 {
                T_COUNT.inc();
                T_HIST.record(*seed * 1000 + k);
                T_GAUGE.set(*seed);
            }
        });
        assert_eq!(T_COUNT.get() - c0, 64 * 1000, "no increment may be lost");
        assert_eq!(T_HIST.count() - h0, 64 * 1000);
        assert!(T_GAUGE.hwm() >= 63, "hwm keeps the max of all threads");
        // Bucket totals must equal the record count (every record lands
        // in exactly one bucket).
        let bucket_sum: u64 = (0..HIST_BUCKETS).map(|i| T_HIST.bucket(i)).sum();
        assert_eq!(bucket_sum, T_HIST.count());
    }

    #[test]
    fn gauge_tracks_value_and_high_water_separately() {
        enable_metrics();
        static G: Gauge = Gauge::new("test.registry.gauge2");
        G.set(9);
        G.set(3);
        assert_eq!(G.value(), 3, "value follows the last set");
        assert_eq!(G.hwm(), 9, "hwm keeps the peak");
    }

    #[test]
    fn registry_json_emits_every_declared_metric() {
        enable_metrics();
        static C: Counter = Counter::new("test.json.counter");
        static G: Gauge = Gauge::new("test.json.gauge");
        static H: Histogram = Histogram::new("test.json.hist");
        H.record(0);
        H.record(u64::MAX);
        let j = registry_json("dqgan.metrics.v1", BTreeMap::new(), &[&C], &[&G], &[&H]);
        let text = j.to_string_compact();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("schema").unwrap().as_str().unwrap(), "dqgan.metrics.v1");
        let counters = back.get("counters").unwrap();
        assert_eq!(counters.get("test.json.counter").unwrap().as_f64().unwrap(), 0.0);
        let hist = back.get("histograms").unwrap().get("test.json.hist").unwrap();
        assert_eq!(hist.get("count").unwrap().as_f64().unwrap(), 2.0);
        let buckets = hist.get("buckets").unwrap();
        assert!(buckets.get("00").is_some(), "zero bucket present");
        assert!(buckets.get("64").is_some(), "u64::MAX bucket present");
    }
}
