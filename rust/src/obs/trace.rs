//! Span tracing to Chrome/Perfetto trace-event JSON.
//!
//! Spans are RAII guards: [`span`] stamps a start time, the guard's
//! `Drop` stamps the end and pushes one `ph:"X"` complete event onto a
//! global buffer, and [`write_trace`] serializes the buffer through
//! `util/json.rs` at run end. Open the file at <https://ui.perfetto.dev>
//! (or `chrome://tracing`) to see the gather/broadcast overlap as lanes.
//!
//! Thread-id convention: the leader's round engine is `tid 0`
//! ([`LEADER_TID`]), in-process worker `i` is `tid 1 + i`
//! ([`worker_tid`]); all events share `pid 1`. Timestamps are
//! microseconds (fractional) since [`enable_trace`], which Perfetto
//! renders as a zero-based timeline.
//!
//! Like the metrics registry, the disabled fast path is a single
//! relaxed atomic load: [`span`] returns an inert guard without reading
//! the clock when tracing is off. When on, each span takes the buffer
//! mutex exactly once (at drop) — acceptable for the round-level spans
//! we emit (tens per round), and never on any per-element path.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Leader round-engine lane.
pub const LEADER_TID: u64 = 0;

/// Lane for in-process worker `id`.
pub fn worker_tid(id: usize) -> u64 {
    1 + id as u64
}

static TRACE_ON: AtomicBool = AtomicBool::new(false);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static EVENTS: Mutex<Vec<TraceEvent>> = Mutex::new(Vec::new());

/// One completed span, pending serialization.
struct TraceEvent {
    name: &'static str,
    tid: u64,
    ts_us: f64,
    dur_us: f64,
    round: u64,
}

/// The one relaxed load every span site gates on.
#[inline(always)]
pub fn trace_enabled() -> bool {
    TRACE_ON.load(Ordering::Relaxed)
}

/// Turn span collection on for the rest of the process lifetime and pin
/// the trace epoch (t = 0) to now.
pub fn enable_trace() {
    EPOCH.get_or_init(Instant::now);
    TRACE_ON.store(true, Ordering::Relaxed);
}

/// RAII span guard: created by [`span`], pushes its event on drop.
/// Inert (no clock read, no buffer touch) when tracing is disabled.
pub struct Span {
    live: Option<(&'static str, u64, u64, Instant)>,
}

/// Open a span named `name` on lane `tid` for `round`. Drop the guard
/// to close it.
#[inline]
pub fn span(name: &'static str, tid: u64, round: u64) -> Span {
    if !trace_enabled() {
        return Span { live: None };
    }
    Span { live: Some((name, tid, round, Instant::now())) }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some((name, tid, round, start)) = self.live.take() else {
            return;
        };
        let epoch = *EPOCH.get().expect("trace enabled implies epoch set");
        let ts_us = start.duration_since(epoch).as_secs_f64() * 1e6;
        let dur_us = start.elapsed().as_secs_f64() * 1e6;
        EVENTS.lock().expect("trace buffer lock").push(TraceEvent {
            name,
            tid,
            ts_us,
            dur_us,
            round,
        });
    }
}

/// Serialize every collected span as a Chrome trace-event document:
/// `{"traceEvents": [{"name", "ph": "X", "ts", "dur", "pid", "tid",
/// "args": {"round"}}, …]}`. The buffer is drained, so a second call
/// only writes spans completed since the first.
pub fn trace_json() -> Json {
    let events = std::mem::take(&mut *EVENTS.lock().expect("trace buffer lock"));
    let arr = events
        .into_iter()
        .map(|e| {
            let mut args = BTreeMap::new();
            args.insert("round".to_string(), Json::Num(e.round as f64));
            let mut obj = BTreeMap::new();
            obj.insert("name".to_string(), Json::Str(e.name.to_string()));
            obj.insert("ph".to_string(), Json::Str("X".to_string()));
            obj.insert("ts".to_string(), Json::Num(e.ts_us));
            obj.insert("dur".to_string(), Json::Num(e.dur_us));
            obj.insert("pid".to_string(), Json::Num(1.0));
            obj.insert("tid".to_string(), Json::Num(e.tid as f64));
            obj.insert("args".to_string(), Json::Obj(args));
            Json::Obj(obj)
        })
        .collect();
    let mut root = BTreeMap::new();
    root.insert("traceEvents".to_string(), Json::Arr(arr));
    Json::Obj(root)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_span_is_inert() {
        // Tracing may already be on if another test enabled it first
        // (process-global flag); only assert inertness when it is off.
        if !trace_enabled() {
            let s = span("test.never", 3, 9);
            assert!(s.live.is_none(), "disabled span must not stamp the clock");
            drop(s);
        }
    }

    #[test]
    fn spans_round_trip_through_the_json_writer() {
        enable_trace();
        {
            let _outer = span("test.outer", LEADER_TID, 4);
            let _inner = span("test.inner", worker_tid(2), 4);
        }
        let doc = trace_json().to_string_compact();
        let back = Json::parse(&doc).unwrap();
        let events = back.get("traceEvents").unwrap().as_arr().unwrap();
        // The drained buffer may also hold spans from concurrently
        // running tests; find ours by name.
        let find = |name: &str| {
            events
                .iter()
                .find(|e| e.get("name").and_then(Json::as_str) == Some(name))
                .unwrap_or_else(|| panic!("span {name} missing from trace"))
        };
        let outer = find("test.outer");
        let inner = find("test.inner");
        for e in [outer, inner] {
            assert_eq!(e.get("ph").unwrap().as_str(), Some("X"));
            assert_eq!(e.get("pid").unwrap().as_f64(), Some(1.0));
            assert!(e.get("ts").unwrap().as_f64().unwrap() >= 0.0);
            assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
            assert_eq!(e.get("args").unwrap().get("round").unwrap().as_f64(), Some(4.0));
        }
        assert_eq!(outer.get("tid").unwrap().as_f64(), Some(0.0));
        assert_eq!(inner.get("tid").unwrap().as_f64(), Some(3.0));
        // Inner opened after and closed before outer: containment holds.
        let o_ts = outer.get("ts").unwrap().as_f64().unwrap();
        let o_end = o_ts + outer.get("dur").unwrap().as_f64().unwrap();
        let i_ts = inner.get("ts").unwrap().as_f64().unwrap();
        let i_end = i_ts + inner.get("dur").unwrap().as_f64().unwrap();
        assert!(i_ts >= o_ts && i_end <= o_end, "inner span nests inside outer");
    }
}
