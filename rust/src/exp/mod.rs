//! Experiment harnesses — one per paper figure / theory claim
//! (DESIGN.md §3 per-experiment index). Each harness prints an aligned
//! table and writes a CSV under `results/` that regenerates the figure's
//! series.
//!
//! | id        | paper artifact                         | module      |
//! |-----------|----------------------------------------|-------------|
//! | fig2      | Fig. 2 — IS/FID vs epoch, CIFAR-10-like | `images`    |
//! | fig3      | Fig. 3 — IS/FID vs epoch, CelebA-like   | `images`    |
//! | fig4      | Fig. 4 — speedup vs workers             | `fig4`      |
//! | synthetic | SYN-A — 2-D mixture mode coverage       | `synthetic` |
//! | bilinear  | SYN-B — GDA cycles, OMD converges       | `bilinear`  |
//! | lemma1    | Lemma 1 — bounded EF residual           | `lemma1`    |
//! | thm3      | Theorem 3 — linear speedup trend        | `thm3`      |

pub mod bilinear;
pub mod fig4;
pub mod images;
pub mod lemma1;
pub mod synthetic;
pub mod thm3;

/// Run an experiment by id. `fast` shrinks every run for smoke tests.
pub fn run(id: &str, fast: bool) -> anyhow::Result<()> {
    match id {
        "fig2" => images::run(images::ImageFigure::Fig2Cifar, fast),
        "fig3" => images::run(images::ImageFigure::Fig3Faces, fast),
        "fig4" => fig4::run(fast),
        "synthetic" | "syn-a" => synthetic::run(fast),
        "bilinear" | "syn-b" => bilinear::run(fast),
        "lemma1" => lemma1::run(fast),
        "thm3" => thm3::run(fast),
        "all" => {
            for id in ["bilinear", "synthetic", "lemma1", "thm3", "fig4", "fig2", "fig3"] {
                println!("\n=== experiment {id} ===");
                run(id, fast)?;
            }
            Ok(())
        }
        other => anyhow::bail!(
            "unknown experiment '{other}' (fig2|fig3|fig4|synthetic|bilinear|lemma1|thm3|all)"
        ),
    }
}
