//! Lemma 1 validation: the error-feedback residual stays bounded,
//!
//!   E‖e_t‖² ≤ 8η²(1−δ)(G² + σ²/B) / δ²,
//!
//! swept over compressors with known δ (top-k fractions and ‖·‖∞ levels).
//! For each configuration we run DQGAN (Algorithm 2) on the MLP-GAN, track
//! max/mean ‖e_t‖², compute the bound from the *measured* G² and the
//! declared δ, and report bound satisfaction plus the predicted 1/δ²
//! scaling of the residual.

use crate::algo::{AlgoKind, DqganWorker, WorkerAlgo};
use crate::compress::{Compressor, CompressorSpec};
use crate::model::{MlpGan, MlpGanConfig};
use crate::optim::LrSchedule;
use crate::tensor::ops;
use crate::telemetry::{results_dir, CsvWriter, Table};
use crate::util::rng::Pcg32;
use std::sync::Arc;

/// One sweep row.
#[derive(Debug, Clone)]
pub struct Lemma1Row {
    pub compressor: String,
    pub delta: f64,
    pub max_err_sq: f32,
    pub mean_err_sq: f32,
    pub bound: f64,
    pub holds: bool,
}

/// Run Algorithm 2 with M=4 on the MLP-GAN, tracking ‖e‖².
fn run_one(spec: &CompressorSpec, eta: f32, rounds: usize, batch: usize) -> Lemma1Row {
    let m = 4usize;
    let mut seed_rng = Pcg32::new(1717);
    let gan = MlpGan::new(MlpGanConfig::default());
    let d = crate::grad::GradientSource::dim(&gan);
    let w0 = crate::grad::GradientSource::init_params(&gan, &mut seed_rng);
    let compressor: Arc<dyn Compressor> = Arc::from(spec.build());
    let delta = compressor.delta(d).unwrap_or(0.0);
    let mut workers: Vec<DqganWorker> = (0..m)
        .map(|_| DqganWorker::new(w0.clone(), LrSchedule::constant(eta), compressor.clone()))
        .collect();
    let mut srcs: Vec<MlpGan> =
        (0..m).map(|_| MlpGan::new(MlpGanConfig::default())).collect();
    let mut rngs: Vec<Pcg32> = (0..m).map(|i| Pcg32::new(5000 + i as u64)).collect();
    let mut max_err = 0.0f32;
    let mut sum_err = 0.0f64;
    let mut g_max_sq = 0.0f32;
    let mut count = 0u64;
    let mut avg = vec![0.0f32; d];
    for _ in 0..rounds {
        let mut payloads = Vec::with_capacity(m);
        for ((wk, src), rng) in workers.iter_mut().zip(&mut srcs).zip(&mut rngs) {
            let prod = wk.produce(src, batch, rng).unwrap();
            max_err = max_err.max(prod.stats.err_norm_sq);
            sum_err += prod.stats.err_norm_sq as f64;
            g_max_sq = g_max_sq.max(prod.stats.grad_norm_sq);
            count += 1;
            payloads.push(prod.dense.to_vec());
        }
        let refs: Vec<&[f32]> = payloads.iter().map(|p| p.as_slice()).collect();
        ops::mean_into(&refs, &mut avg);
        for wk in workers.iter_mut() {
            wk.apply(&avg);
        }
    }
    // σ²/B estimate: per-coordinate gradient noise is dwarfed by G² here;
    // use the conservative G² + G²/B envelope.
    let g2 = g_max_sq as f64;
    let sigma_sq_over_b = g2 / batch as f64;
    let bound = if delta > 0.0 {
        8.0 * (eta as f64).powi(2) * (1.0 - delta) * (g2 + sigma_sq_over_b) / (delta * delta)
    } else {
        f64::INFINITY
    };
    Lemma1Row {
        compressor: compressor.name(),
        delta,
        max_err_sq: max_err,
        mean_err_sq: (sum_err / count as f64) as f32,
        bound,
        holds: (max_err as f64) <= bound,
    }
}

pub fn run(fast: bool) -> anyhow::Result<()> {
    let rounds = if fast { 100 } else { 1000 };
    let eta = 0.02f32;
    let batch = 16;
    let sweep: Vec<CompressorSpec> = vec![
        CompressorSpec::parse("topk(f=0.05)")?,
        CompressorSpec::parse("topk(f=0.1)")?,
        CompressorSpec::parse("topk(f=0.25)")?,
        CompressorSpec::parse("topk(f=0.5)")?,
        CompressorSpec::parse("linf(s=3)")?,
        CompressorSpec::parse("linf(s=7)")?,
        CompressorSpec::parse("linf(s=31)")?,
        CompressorSpec::parse("linf8")?,
        CompressorSpec::parse("identity")?,
    ];
    let mut rows = Vec::new();
    for spec in &sweep {
        rows.push(run_one(spec, eta, rounds, batch));
    }

    let mut table =
        Table::new(&["compressor", "δ", "max‖e‖²", "mean‖e‖²", "bound", "holds"]);
    let csv_path = results_dir()?.join("lemma1.csv");
    let mut csv = CsvWriter::create(
        &csv_path,
        &["compressor", "delta", "max_err_sq", "mean_err_sq", "bound", "holds"],
    )?;
    for r in &rows {
        table.row(&[
            r.compressor.clone(),
            format!("{:.4}", r.delta),
            format!("{:.3e}", r.max_err_sq),
            format!("{:.3e}", r.mean_err_sq),
            format!("{:.3e}", r.bound),
            r.holds.to_string(),
        ]);
        csv.row(&[
            r.compressor.clone(),
            format!("{:.6}", r.delta),
            format!("{:.6e}", r.max_err_sq),
            format!("{:.6e}", r.mean_err_sq),
            format!("{:.6e}", r.bound),
            r.holds.to_string(),
        ])?;
    }
    table.print();
    println!("wrote {}", csv.finish()?);

    let violations = rows.iter().filter(|r| !r.holds).count();
    anyhow::ensure!(violations == 0, "Lemma 1 bound violated in {violations} configs");
    println!("Lemma 1 bound holds in all {} configurations ✓", rows.len());
    // δ-scaling sanity: smaller δ ⇒ larger residual (monotone trend on topk).
    let topk: Vec<&Lemma1Row> =
        rows.iter().filter(|r| r.compressor.starts_with("topk")).collect();
    if topk.len() >= 2 {
        let first = topk.first().unwrap();
        let last = topk.last().unwrap();
        println!(
            "1/δ² trend (top-k): δ={:.2} → mean‖e‖²={:.2e} vs δ={:.2} → {:.2e}",
            first.delta, first.mean_err_sq, last.delta, last.mean_err_sq
        );
    }
    let _ = AlgoKind::parse("dqgan:linf8"); // keep the import meaningful
    Ok(())
}
