//! Theorem 3 validation: non-asymptotic convergence of
//! (1/T)·Σ E‖(1/M)Σ_m F(w_{t−½}; ξ_t)‖² and the **linear speedup** claim —
//! with more workers M (or larger batch B) the stationarity measure after
//! a fixed number of rounds is smaller, dominated by the 48σ²/(BM) term.
//!
//! Swept on the MLP-GAN with DQGAN (Algorithm 2, 8-bit linf): M ∈
//! {1,2,4,8}, B ∈ {8,32}, plus a δ sweep at fixed M showing the
//! (1−δ)/δ² penalty term's effect.

use crate::algo::AlgoKind;
use crate::model::{MlpGan, MlpGanConfig};
use crate::optim::LrSchedule;
use crate::ps::{run_cluster, ClusterConfig};
use crate::telemetry::{results_dir, CsvWriter, Table};

/// One sweep row.
#[derive(Debug, Clone)]
pub struct Thm3Row {
    pub algo: String,
    pub workers: usize,
    pub batch: usize,
    /// (1/T)·Σ_t ‖q̄_t/η‖² — the Theorem-3 measure computed from the
    /// averaged payloads (η-unscaled).
    pub avg_stationarity: f64,
    /// Same over the last quarter of training (steady state).
    pub tail_stationarity: f64,
}

fn run_one(algo: &str, m: usize, batch: usize, rounds: u64, eta: f32) -> anyhow::Result<Thm3Row> {
    let cfg = ClusterConfig {
        algo: AlgoKind::parse(algo)?,
        workers: m,
        batch,
        rounds,
        lr: LrSchedule::constant(eta),
        seed: 4242,
        eval_every: 0,
        keep_stats: false,
        agg: Default::default(),
        transport: Default::default(),
        chaos_kill: None,
    };
    let report = run_cluster(&cfg, |_m| Ok(Box::new(MlpGan::new(MlpGanConfig::default()))))?;
    // avg_payload_norm_sq = ‖q̄‖² = ‖η·(1/M)ΣF + EF noise‖²; divide by η².
    let eta2 = (eta as f64) * (eta as f64);
    let vals: Vec<f64> =
        report.records.iter().map(|r| r.avg_payload_norm_sq as f64 / eta2).collect();
    let avg = vals.iter().sum::<f64>() / vals.len() as f64;
    let tail = &vals[vals.len() * 3 / 4..];
    let tail_avg = tail.iter().sum::<f64>() / tail.len() as f64;
    Ok(Thm3Row {
        algo: algo.to_string(),
        workers: m,
        batch,
        avg_stationarity: avg,
        tail_stationarity: tail_avg,
    })
}

pub fn run(fast: bool) -> anyhow::Result<()> {
    let rounds: u64 = if fast { 200 } else { 2000 };
    let eta = 0.02f32;
    let mut rows = Vec::new();
    // Linear-speedup sweep over M.
    for m in [1usize, 2, 4, 8] {
        rows.push(run_one("dqgan:linf8", m, 8, rounds, eta)?);
    }
    // Batch sweep at M=4.
    rows.push(run_one("dqgan:linf8", 4, 32, rounds, eta)?);
    // δ sweep at M=4,B=8: coarser compressor ⇒ larger stationarity.
    for spec in ["dqgan:linf(s=3)", "dqgan:linf(s=15)", "dqgan:identity"] {
        rows.push(run_one(spec, 4, 8, rounds, eta)?);
    }

    let mut table = Table::new(&["algo", "M", "B", "avg‖F̄‖²", "tail‖F̄‖²"]);
    let csv_path = results_dir()?.join("thm3.csv");
    let mut csv = CsvWriter::create(
        &csv_path,
        &["algo", "workers", "batch", "avg_stationarity", "tail_stationarity"],
    )?;
    for r in &rows {
        table.row(&[
            r.algo.clone(),
            r.workers.to_string(),
            r.batch.to_string(),
            format!("{:.4e}", r.avg_stationarity),
            format!("{:.4e}", r.tail_stationarity),
        ]);
        csv.row(&[
            r.algo.clone(),
            r.workers.to_string(),
            r.batch.to_string(),
            format!("{:.6e}", r.avg_stationarity),
            format!("{:.6e}", r.tail_stationarity),
        ])?;
    }
    table.print();
    println!("wrote {}", csv.finish()?);

    // Speedup-shape check: tail stationarity should not grow with M
    // (variance averaging), i.e. M=8 ≤ M=1 · slack.
    let tail_of = |m: usize| {
        rows.iter()
            .find(|r| r.workers == m && r.batch == 8 && r.algo == "dqgan:linf8")
            .map(|r| r.tail_stationarity)
            .unwrap_or(f64::NAN)
    };
    let (t1, t8) = (tail_of(1), tail_of(8));
    println!(
        "linear-speedup trend: tail‖F̄‖² M=1: {t1:.3e} vs M=8: {t8:.3e} ({})",
        if t8 <= t1 * 1.5 { "averaging helps ✓" } else { "UNEXPECTED" }
    );
    Ok(())
}
