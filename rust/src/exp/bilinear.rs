//! SYN-B: the bilinear min–max game (paper §2.2 motivation). Plots the
//! distance-to-solution trajectory for simultaneous GDA (cycles/diverges),
//! one-call OMD, two-call extragradient, and distributed DQGAN — the
//! experiment behind the claim that "gradient descent type algorithms …
//! may fail to converge when dealing with min-max problems".

use crate::algo::AlgoKind;
use crate::grad::GradientSource;
use crate::model::BilinearGame;
use crate::optim::{Extragradient, LrSchedule, Omd, Optimizer, Sgd};
use crate::ps::{run_cluster, ClusterConfig};
use crate::telemetry::{results_dir, CsvWriter, Table};
use crate::util::rng::Pcg32;

/// One trajectory point.
#[derive(Debug, Clone)]
pub struct TrajPoint {
    pub method: String,
    pub iter: u64,
    pub dist: f32,
}

fn game() -> BilinearGame {
    let mut rng = Pcg32::new(7);
    BilinearGame::random(4, 0.0, &mut rng)
}

/// Single-machine trajectories for GDA / OMD / extragradient.
fn single_machine(eta: f32, iters: u64, every: u64) -> Vec<TrajPoint> {
    let mut out = Vec::new();
    // GDA
    {
        let mut g = game();
        let mut rng = Pcg32::new(1);
        let mut w = g.init_params(&mut rng);
        let mut sgd = Sgd::new(eta);
        let mut grad = vec![0.0; w.len()];
        for t in 0..iters {
            if t % every == 0 {
                out.push(TrajPoint {
                    method: "GDA".into(),
                    iter: t,
                    dist: g.dist_to_solution(&w),
                });
            }
            let mut r = Pcg32::new(t);
            crate::grad::GradientSource::grad(&mut g, &w, 1, &mut r, &mut grad).unwrap();
            sgd.step(&mut w, &grad);
            if !w.iter().all(|x| x.is_finite()) || g.dist_to_solution(&w) > 1e6 {
                break; // diverged — expected for GDA
            }
        }
    }
    // OMD
    {
        let mut g = game();
        let mut rng = Pcg32::new(1);
        let mut w = g.init_params(&mut rng);
        let mut omd = Omd::new(eta, w.len());
        for t in 0..iters {
            if t % every == 0 {
                out.push(TrajPoint {
                    method: "OMD".into(),
                    iter: t,
                    dist: g.dist_to_solution(&w),
                });
            }
            let mut r = Pcg32::new(t);
            omd.step_with(&mut w, |p, o| {
                crate::grad::GradientSource::grad(&mut g, p, 1, &mut r, o).unwrap();
            });
        }
    }
    // Extragradient
    {
        let mut g = game();
        let mut rng = Pcg32::new(1);
        let mut w = g.init_params(&mut rng);
        let mut eg = Extragradient::new(eta);
        for t in 0..iters {
            if t % every == 0 {
                out.push(TrajPoint {
                    method: "Extragradient".into(),
                    iter: t,
                    dist: g.dist_to_solution(&w),
                });
            }
            let mut r = Pcg32::new(t);
            eg.step_with(&mut w, |p, o| {
                crate::grad::GradientSource::grad(&mut g, p, 1, &mut r, o).unwrap();
            });
        }
    }
    out
}

/// Distributed DQGAN (Algorithm 2) on the same game via the PS runtime.
fn distributed_dqgan(eta: f32, rounds: u64, every: u64) -> anyhow::Result<Vec<TrajPoint>> {
    let cfg = ClusterConfig {
        algo: AlgoKind::parse("dqgan:linf8")?,
        workers: 4,
        batch: 4,
        rounds,
        lr: LrSchedule::constant(eta),
        seed: 31,
        eval_every: every,
        keep_stats: false,
        agg: Default::default(),
        transport: Default::default(),
        chaos_kill: None,
    };
    let report = run_cluster(&cfg, |_m| Ok(Box::new(game())))?;
    let g = game();
    Ok(report
        .evals
        .iter()
        .map(|ev| TrajPoint {
            method: "DQGAN(M=4,8bit)".into(),
            iter: ev.round,
            dist: g.dist_to_solution(&ev.params),
        })
        .collect())
}

pub fn run(fast: bool) -> anyhow::Result<()> {
    let iters: u64 = if fast { 500 } else { 5000 };
    let every = (iters / 25).max(1);
    let eta = 0.1;
    let mut all = single_machine(eta, iters, every);
    all.extend(distributed_dqgan(eta, iters, every)?);

    let csv_path = results_dir()?.join("bilinear.csv");
    let mut csv = CsvWriter::create(&csv_path, &["method", "iter", "dist"])?;
    for p in &all {
        csv.row(&[p.method.clone(), p.iter.to_string(), format!("{:.6}", p.dist)])?;
    }

    // Summarize: first and last distance per method.
    let mut table = Table::new(&["method", "dist(0)", "dist(end)", "verdict"]);
    for m in ["GDA", "OMD", "Extragradient", "DQGAN(M=4,8bit)"] {
        let pts: Vec<&TrajPoint> = all.iter().filter(|p| p.method == m).collect();
        if pts.is_empty() {
            continue;
        }
        let d0 = pts.first().unwrap().dist;
        let dend = pts.last().unwrap().dist;
        let verdict = if m == "GDA" {
            if dend > d0 { "diverges ✓ (paper claim)" } else { "bounded?" }
        } else if dend < 0.1 * d0 {
            "converges ✓"
        } else {
            "slow"
        };
        table.row(&[
            m.to_string(),
            format!("{d0:.3}"),
            format!("{dend:.4}"),
            verdict.to_string(),
        ]);
    }
    table.print();
    println!("wrote {}", csv.finish()?);
    Ok(())
}
