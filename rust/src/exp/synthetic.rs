//! SYN-A: GAN on the 2-D ring-of-8 Gaussian mixture (the "synthetic
//! dataset" of the abstract). Trains {CPOAdam, CPOAdam-GQ, DQGAN(Alg 2),
//! DQGAN-Adam} through the PS runtime on the native MLP-GAN and reports
//! mode coverage + the quality score per epoch.
//!
//! Expected shape: all OMD/optimistic methods cover (most of) the 8 modes;
//! DQGAN tracks CPOAdam closely; the quantized-no-EF baseline is worse or
//! noisier; GDA (included for reference) is unstable.

use crate::algo::AlgoKind;
use crate::data::GaussianMixture2D;
use crate::model::{MlpGan, MlpGanConfig};
use crate::optim::LrSchedule;
use crate::ps::{run_cluster, ClusterConfig};
use crate::telemetry::{results_dir, CsvWriter, Table};
use crate::util::rng::Pcg32;

/// One measurement row.
#[derive(Debug, Clone)]
pub struct SynPoint {
    pub method: String,
    pub round: u64,
    pub coverage: f32,
    pub quality: f32,
    pub loss_d: f32,
}

fn gan() -> MlpGan {
    MlpGan::new(MlpGanConfig::default())
}

/// Train one method, score snapshots with the generator sampler.
pub fn run_method(
    algo_str: &str,
    label: &str,
    rounds: u64,
    lr: f32,
    seed: u64,
) -> anyhow::Result<Vec<SynPoint>> {
    let cfg = ClusterConfig {
        algo: AlgoKind::parse(algo_str)?,
        workers: 4,
        batch: 32,
        rounds,
        lr: LrSchedule::constant(lr),
        seed,
        eval_every: (rounds / 10).max(1),
        keep_stats: false,
        agg: Default::default(),
        transport: Default::default(),
        chaos_kill: None,
    };
    let report = run_cluster(&cfg, |_m| Ok(Box::new(gan())))?;
    let scorer = gan();
    let mixture = GaussianMixture2D::ring(8, 2.0, 0.1);
    let mut rng = Pcg32::new(seed ^ 0xABCD);
    let mut out = Vec::new();
    for ev in &report.evals {
        let pts = scorer.sample_generator(&ev.params, 512, &mut rng);
        out.push(SynPoint {
            method: label.to_string(),
            round: ev.round,
            coverage: mixture.mode_coverage(&pts),
            quality: mixture.quality_score(&pts),
            loss_d: ev.loss_d.unwrap_or(f32::NAN),
        });
    }
    Ok(out)
}

pub fn run(fast: bool) -> anyhow::Result<()> {
    let rounds: u64 = if fast { 200 } else { 4000 };
    let methods = [
        ("cpoadam", "CPOAdam", 2e-3f32),
        ("cpoadam-gq:linf8", "CPOAdam-GQ", 2e-3),
        ("dqgan-adam:linf8", "DQGAN", 2e-3),
        ("dqgan:linf8", "DQGAN-OMD(Alg2)", 2e-2),
    ];
    let mut all = Vec::new();
    for (algo, label, lr) in methods {
        crate::log_info!("=== synthetic / {label} ===");
        all.extend(run_method(algo, label, rounds, lr, 99)?);
    }

    let mut table = Table::new(&["method", "round", "coverage", "quality", "loss_D"]);
    let csv_path = results_dir()?.join("synthetic.csv");
    let mut csv =
        CsvWriter::create(&csv_path, &["method", "round", "coverage", "quality", "loss_d"])?;
    for p in &all {
        table.row(&[
            p.method.clone(),
            p.round.to_string(),
            format!("{:.3}", p.coverage),
            format!("{:.3}", p.quality),
            format!("{:.3}", p.loss_d),
        ]);
        csv.row(&[
            p.method.clone(),
            p.round.to_string(),
            format!("{:.4}", p.coverage),
            format!("{:.4}", p.quality),
            format!("{:.4}", p.loss_d),
        ])?;
    }
    table.print();
    println!("wrote {}", csv.finish()?);

    let final_of = |m: &str| all.iter().filter(|p| p.method == m).next_back().cloned();
    if let (Some(cp), Some(dq)) = (final_of("CPOAdam"), final_of("DQGAN")) {
        println!(
            "final: CPOAdam coverage={:.2} quality={:.3} | DQGAN coverage={:.2} quality={:.3}",
            cp.coverage, cp.quality, dq.coverage, dq.quality
        );
    }
    Ok(())
}
