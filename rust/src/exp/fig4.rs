//! Figure 4: speedup vs number of workers M ∈ {1,2,4,8,16,32} on the
//! CIFAR-10-like (50k samples) and CelebA-like (200k samples) datasets,
//! comparing DQGAN-8bit against CPOAdam-fp32.
//!
//! Method (DESIGN.md §5): the per-round *compute* time is **measured** on
//! this host by running real rounds through the XLA runtime (gradient +
//! quantize + encode), and the *communication* time comes from the
//! byte-exact payload sizes fed into the [`NetworkModel`] PS cost model.
//! Speedup(M) = epoch_time(1) / epoch_time(M). The paper's shape to
//! reproduce: speedup grows with M and DQGAN-8bit's lead over
//! CPOAdam-32bit widens with M (it ships ~4× fewer uplink bytes).

use crate::algo::AlgoKind;
use crate::comm::NetworkModel;
use crate::data::SynthImages;
use crate::grad::GradientSource;
use crate::runtime::{Runtime, XlaGradSource};
use crate::telemetry::{results_dir, CsvWriter, Table};
use crate::util::rng::Pcg32;
use crate::util::timer::Stopwatch;

/// Measured per-round costs of one worker.
#[derive(Debug, Clone)]
pub struct MeasuredRound {
    /// Gradient + quantize + encode wall seconds per round.
    pub t_compute: f64,
    /// Uplink payload bytes per worker per round.
    pub bytes_up: usize,
    /// Downlink (broadcast) bytes per worker per round.
    pub bytes_down: usize,
}

/// Measure the real per-round compute cost for a method on this host,
/// using exactly the production worker path: the XLA gradient artifact +
/// the **native** linf8 quantizer (what `DqganAdamWorker` runs; the
/// interpret-mode Pallas kernel is the correctness twin, benchmarked
/// separately in `bench_quantizers`).
pub fn measure_round(
    rt: &Runtime,
    quantized: bool,
    reps: usize,
) -> anyhow::Result<MeasuredRound> {
    use crate::compress::compressor_from_spec;
    let mut src = XlaGradSource::dcgan(rt, SynthImages::cifar_like(1))?;
    let d = src.dim();
    let batch = src.artifact_batch();
    let mut rng = Pcg32::new(4242);
    let w = src.init_params(&mut rng);
    let mut g = vec![0.0; d];
    let quantizer: Option<Box<dyn crate::compress::Compressor>> =
        if quantized { Some(compressor_from_spec("linf8")?) } else { None };
    // Warm up the artifact compile.
    src.grad(&w, batch, &mut rng, &mut g)?;
    let sw = Stopwatch::start();
    let mut bytes_up = 0usize;
    let mut wire = Vec::new();
    for _ in 0..reps {
        src.grad(&w, batch, &mut rng, &mut g)?;
        if let Some(q) = &quantizer {
            wire.clear();
            let _dense = q.compress_encoded(&g, &mut rng, &mut wire);
            bytes_up = wire.len();
        } else {
            bytes_up = 4 * d;
        }
    }
    Ok(MeasuredRound {
        t_compute: sw.elapsed_secs() / reps as f64,
        bytes_up,
        bytes_down: 4 * d, // server broadcasts full-precision q̄
    })
}

/// One speedup series row.
#[derive(Debug, Clone)]
pub struct SpeedupPoint {
    pub dataset: &'static str,
    pub method: &'static str,
    pub workers: usize,
    pub epoch_secs: f64,
    pub speedup: f64,
}

/// Compute the speedup table from measured rounds.
pub fn speedup_series(
    measured: &MeasuredRound,
    dataset: &'static str,
    method: &'static str,
    samples: usize,
    batch: usize,
    net: &NetworkModel,
    worker_counts: &[usize],
) -> Vec<SpeedupPoint> {
    let t1 = net.epoch_time(
        samples,
        batch,
        1,
        measured.t_compute,
        measured.bytes_up,
        measured.bytes_down,
    );
    worker_counts
        .iter()
        .map(|&m| {
            let tm = net.epoch_time(
                samples,
                batch,
                m,
                measured.t_compute,
                measured.bytes_up,
                measured.bytes_down,
            );
            SpeedupPoint {
                dataset,
                method,
                workers: m,
                epoch_secs: tm,
                speedup: t1 / tm,
            }
        })
        .collect()
}

pub fn run(fast: bool) -> anyhow::Result<()> {
    let rt = Runtime::from_default_dir()?;
    let reps = if fast { 2 } else { 8 };
    crate::log_info!("measuring per-round compute (reps={reps})...");
    let m_dqgan = measure_round(&rt, true, reps)?;
    let m_cpo = measure_round(&rt, false, reps)?;
    crate::log_info!(
        "measured: dqgan {:.1} ms/round {} B up | cpoadam {:.1} ms/round {} B up",
        m_dqgan.t_compute * 1e3,
        m_dqgan.bytes_up,
        m_cpo.t_compute * 1e3,
        m_cpo.bytes_up
    );

    let net = NetworkModel::ten_gbe();
    let workers = [1usize, 2, 4, 8, 16, 32];
    let batch = 16;
    // CIFAR-10 has 50k train images; CelebA ≈ 200k.
    let datasets: [(&str, usize); 2] = [("cifar-like", 50_000), ("celeba-like", 200_000)];

    let mut rows = Vec::new();
    for (ds, samples) in datasets {
        rows.extend(speedup_series(&m_dqgan, ds, "DQGAN-8bit", samples, batch, &net, &workers));
        rows.extend(speedup_series(&m_cpo, ds, "CPOAdam-fp32", samples, batch, &net, &workers));
    }

    let mut table = Table::new(&["dataset", "method", "M", "epoch_s", "speedup"]);
    let csv_path = results_dir()?.join("fig4.csv");
    let mut csv = CsvWriter::create(
        &csv_path,
        &["dataset", "method", "workers", "epoch_secs", "speedup"],
    )?;
    for r in &rows {
        table.row(&[
            r.dataset.to_string(),
            r.method.to_string(),
            r.workers.to_string(),
            format!("{:.2}", r.epoch_secs),
            format!("{:.2}", r.speedup),
        ]);
        csv.row(&[
            r.dataset.to_string(),
            r.method.to_string(),
            r.workers.to_string(),
            format!("{:.4}", r.epoch_secs),
            format!("{:.4}", r.speedup),
        ])?;
    }
    table.print();
    println!("wrote {}", csv.finish()?);

    // Shape check: at M=32 DQGAN should beat CPOAdam on both datasets.
    for (ds, _) in datasets {
        let get = |method: &str| {
            rows.iter()
                .find(|r| r.dataset == ds && r.method == method && r.workers == 32)
                .map(|r| r.speedup)
                .unwrap_or(0.0)
        };
        let dq = get("DQGAN-8bit");
        let cp = get("CPOAdam-fp32");
        println!(
            "{ds}: speedup@32 DQGAN-8bit={dq:.2} vs CPOAdam-fp32={cp:.2} ({})",
            if dq > cp { "8-bit wins ✓ (paper shape holds)" } else { "UNEXPECTED" }
        );
    }
    // Also report the uplink-byte ratio (the mechanism behind the gap).
    let d = AlgoKind::parse("cpoadam")?.uplink_bytes(400_708);
    let q = AlgoKind::parse("dqgan-adam:linf8")?.uplink_bytes(400_708);
    println!("uplink bytes/round/worker: fp32={d} vs 8-bit={q} ({:.2}× less)", d as f64 / q as f64);
    Ok(())
}
