//! Figures 2 & 3: proxy IS / proxy FID vs epoch for
//! {CPOAdam, CPOAdam-GQ(8-bit), DQGAN(8-bit)} on the CIFAR-10-like and
//! CelebA-like synthetic image datasets, trained through the full stack
//! (Rust PS runtime → XLA DCGAN artifacts → Pallas matmul inside).
//!
//! Figure-shape expectations (paper §4): CPOAdam best; DQGAN within a
//! small gap (≤0.6 IS / ≤30 FID on CIFAR-10, ≤0.5 / ≤40 on CelebA);
//! CPOAdam-GQ worse — quantization without EF costs quality.

use crate::algo::AlgoKind;
use crate::data::{SynthImages, IMG_LEN};
use crate::metrics::{fid_from_features, inception_score, FeatureNet, FEATURE_DIM};
use crate::optim::LrSchedule;
use crate::ps::{run_cluster, ClusterConfig};
use crate::runtime::{Runtime, XlaGradSource, XlaSampler};
use crate::telemetry::{results_dir, CsvWriter, Table};
use crate::util::rng::Pcg32;

/// Which image figure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ImageFigure {
    Fig2Cifar,
    Fig3Faces,
}

impl ImageFigure {
    pub fn id(self) -> &'static str {
        match self {
            Self::Fig2Cifar => "fig2",
            Self::Fig3Faces => "fig3",
        }
    }

    fn dataset(self, seed: u64) -> SynthImages {
        match self {
            Self::Fig2Cifar => SynthImages::cifar_like(seed),
            Self::Fig3Faces => SynthImages::faces_like(seed),
        }
    }
}

/// One (method, epoch) measurement.
#[derive(Debug, Clone)]
pub struct EpochPoint {
    pub method: String,
    pub epoch: usize,
    pub inception: f32,
    pub fid: f32,
    pub loss_g: f32,
    pub loss_d: f32,
    pub bytes_up: u64,
}

/// Experiment parameters (shrunk by `fast`).
#[derive(Debug, Clone)]
pub struct ImageExpConfig {
    pub workers: usize,
    pub epochs: usize,
    pub rounds_per_epoch: u64,
    pub eval_images: usize,
    pub seed: u64,
    pub dqgan_lr: f32,
    pub adam_lr: f32,
}

impl ImageExpConfig {
    pub fn new(fast: bool) -> Self {
        if fast {
            Self {
                workers: 2,
                epochs: 2,
                rounds_per_epoch: 3,
                eval_images: 64,
                seed: 2020,
                dqgan_lr: 2e-4,
                adam_lr: 2e-4,
            }
        } else {
            // Sized for a single-CPU testbed: each dcgan_grad call is
            // ~0.3 s, so M=2 × 200 rounds ≈ 3 min per method. lr 2e-4 is
            // the DCGAN convention; higher rates destabilize the WGAN
            // critic (verified: 5e-4 diverges).
            Self {
                workers: 2,
                epochs: 8,
                rounds_per_epoch: 25,
                eval_images: 128,
                seed: 2020,
                dqgan_lr: 2e-4,
                adam_lr: 2e-4,
            }
        }
    }
}

/// Score a parameter snapshot: proxy IS + FID against `reference`.
pub fn score_snapshot(
    sampler: &XlaSampler,
    net: &FeatureNet,
    w: &[f32],
    reference_feats: &[f32],
    n_ref: usize,
    eval_images: usize,
    rng: &mut Pcg32,
) -> anyhow::Result<(f32, f32)> {
    let mut imgs = Vec::with_capacity(eval_images * IMG_LEN);
    while imgs.len() < eval_images * IMG_LEN {
        imgs.extend(sampler.sample(w, rng)?);
    }
    imgs.truncate(eval_images * IMG_LEN);
    let (feats, logits) = net.features_batch(&imgs);
    let is = inception_score(&logits, eval_images);
    let fid =
        fid_from_features(&feats, eval_images, reference_feats, n_ref, FEATURE_DIM).fid;
    Ok((is, fid))
}

/// Train one method and return its per-epoch curve.
#[allow(clippy::too_many_arguments)]
fn run_method(
    rt: &Runtime,
    figure: ImageFigure,
    algo: AlgoKind,
    label: &str,
    cfg: &ImageExpConfig,
    net: &FeatureNet,
    reference_feats: &[f32],
    n_ref: usize,
) -> anyhow::Result<Vec<EpochPoint>> {
    let lr = match algo {
        AlgoKind::Dqgan { .. } => LrSchedule::constant(cfg.dqgan_lr),
        _ => LrSchedule::constant(cfg.adam_lr),
    };
    let cluster = ClusterConfig {
        algo,
        workers: cfg.workers,
        batch: 16, // must match the dcgan_grad artifact export
        rounds: cfg.epochs as u64 * cfg.rounds_per_epoch,
        lr,
        seed: cfg.seed,
        eval_every: cfg.rounds_per_epoch,
        keep_stats: true,
        agg: Default::default(),
        transport: Default::default(),
        chaos_kill: None,
    };
    let figure_seed = cfg.seed ^ 0x1111;
    let report = run_cluster(&cluster, |m| {
        let src =
            XlaGradSource::dcgan(rt, figure.dataset(figure_seed))?;
        let _ = m;
        Ok(Box::new(src))
    })?;
    let sampler = XlaSampler::new(rt, "dcgan_sample")?;
    let mut rng = Pcg32::new(cfg.seed ^ 0xE7A1);
    let mut points = Vec::new();
    for (i, ev) in report.evals.iter().enumerate() {
        let (is, fid) = score_snapshot(
            &sampler,
            net,
            &ev.params,
            reference_feats,
            n_ref,
            cfg.eval_images,
            &mut rng,
        )?;
        points.push(EpochPoint {
            method: label.to_string(),
            epoch: i,
            inception: is,
            fid,
            loss_g: ev.loss_g.unwrap_or(f32::NAN),
            loss_d: ev.loss_d.unwrap_or(f32::NAN),
            bytes_up: report.total_bytes_up,
        });
        crate::log_info!(
            "{label} epoch {i}: IS={is:.3} FID={fid:.1} lossG={:.3} lossD={:.3}",
            ev.loss_g.unwrap_or(f32::NAN),
            ev.loss_d.unwrap_or(f32::NAN)
        );
    }
    Ok(points)
}


/// Run the full figure: 3 methods × epochs, print + CSV.
pub fn run(figure: ImageFigure, fast: bool) -> anyhow::Result<()> {
    let cfg = ImageExpConfig::new(fast);
    let rt = Runtime::from_default_dir()?;
    let net = FeatureNet::new();
    // Reference features from the real distribution (shared across methods).
    let ds = figure.dataset(cfg.seed ^ 0x1111);
    let n_ref = cfg.eval_images.max(128);
    let mut rng = Pcg32::new(cfg.seed ^ 0x4EF5);
    let (ref_imgs, _) = ds.sample_batch(n_ref, &mut rng);
    let (reference_feats, _) = net.features_batch(&ref_imgs);

    let methods: Vec<(&str, AlgoKind)> = vec![
        ("CPOAdam", AlgoKind::parse("cpoadam")?),
        ("CPOAdam-GQ", AlgoKind::parse("cpoadam-gq:linf8")?),
        ("DQGAN", AlgoKind::parse("dqgan-adam:linf8")?),
    ];
    let mut all = Vec::new();
    for (label, algo) in methods {
        crate::log_info!("=== {} / {label} ===", figure.id());
        let pts =
            run_method(&rt, figure, algo, label, &cfg, &net, &reference_feats, n_ref)?;
        all.extend(pts);
    }

    // Print + CSV.
    let mut table = Table::new(&["method", "epoch", "IS", "FID", "loss_G", "loss_D"]);
    let csv_path = results_dir()?.join(format!("{}.csv", figure.id()));
    let mut csv = CsvWriter::create(
        &csv_path,
        &["method", "epoch", "inception_score", "fid", "loss_g", "loss_d", "bytes_up"],
    )?;
    for p in &all {
        table.row(&[
            p.method.clone(),
            p.epoch.to_string(),
            format!("{:.3}", p.inception),
            format!("{:.1}", p.fid),
            format!("{:.3}", p.loss_g),
            format!("{:.3}", p.loss_d),
        ]);
        csv.row(&[
            p.method.clone(),
            p.epoch.to_string(),
            format!("{:.4}", p.inception),
            format!("{:.3}", p.fid),
            format!("{:.4}", p.loss_g),
            format!("{:.4}", p.loss_d),
            p.bytes_up.to_string(),
        ])?;
    }
    table.print();
    println!("wrote {}", csv.finish()?);

    // Figure-shape summary (final epoch).
    let last = |m: &str| {
        all.iter().filter(|p| p.method == m).next_back().cloned()
    };
    if let (Some(cp), Some(dq), Some(gq)) =
        (last("CPOAdam"), last("DQGAN"), last("CPOAdam-GQ"))
    {
        println!(
            "final-epoch gap: DQGAN vs CPOAdam ΔIS={:+.3} ΔFID={:+.1} | \
             CPOAdam-GQ vs CPOAdam ΔIS={:+.3} ΔFID={:+.1}",
            dq.inception - cp.inception,
            dq.fid - cp.fid,
            gq.inception - cp.inception,
            gq.fid - cp.fid,
        );
    }
    Ok(())
}

