//! Matrix square root via the Newton–Schulz iteration (Denman–Beavers
//! variant with scaling), used by FID:
//!
//!   FID = ‖μ₁−μ₂‖² + Tr(Σ₁ + Σ₂ − 2·(Σ₁Σ₂)^{1/2})
//!
//! Newton–Schulz converges quadratically for matrices with spectrum in
//! (0, 2) after normalization by the Frobenius norm; it only needs
//! matmuls, which keeps this dependency-free. The input is symmetrized and
//! regularized (`eps·I`) first, matching the common FID implementations.

use super::{eye, fro_norm, matmul_sq, trace};

/// Diagnostics from a sqrtm computation.
#[derive(Debug, Clone)]
pub struct SqrtmReport {
    pub iterations: usize,
    pub residual: f32, // ‖Y·Y − A‖_F / ‖A‖_F
    pub converged: bool,
}

/// Newton–Schulz matrix square root of a (nearly) SPD matrix `a` (n×n).
/// Returns (Y ≈ A^{1/2}, report). `eps` is added to the diagonal for
/// conditioning; `max_iter` bounds the iteration count.
pub fn sqrtm_newton_schulz(
    a: &[f32],
    n: usize,
    eps: f32,
    max_iter: usize,
) -> (Vec<f32>, SqrtmReport) {
    assert_eq!(a.len(), n * n);
    // Symmetrize + regularize.
    let mut m = vec![0.0f32; n * n];
    for i in 0..n {
        for j in 0..n {
            m[i * n + j] = 0.5 * (a[i * n + j] + a[j * n + i]);
        }
        m[i * n + i] += eps;
    }
    let norm = fro_norm(&m).max(1e-12);
    let inv_norm = 1.0 / norm;
    // Y0 = A/‖A‖, Z0 = I
    let mut y: Vec<f32> = m.iter().map(|&v| v * inv_norm).collect();
    let mut z = eye(n);
    let id = eye(n);

    let mut iterations = 0;
    for it in 0..max_iter {
        iterations = it + 1;
        // T = (3I − Z·Y) / 2
        let zy = matmul_sq(&z, &y, n);
        let mut t = vec![0.0f32; n * n];
        for i in 0..n * n {
            t[i] = 0.5 * (3.0 * id[i] - zy[i]);
        }
        let y_next = matmul_sq(&y, &t, n);
        let z_next = matmul_sq(&t, &z, n);
        // Convergence check on the normalized iterate.
        let mut delta = 0.0f64;
        for i in 0..n * n {
            delta += ((y_next[i] - y[i]) as f64).powi(2);
        }
        y = y_next;
        z = z_next;
        if delta.sqrt() < 1e-7 {
            break;
        }
    }
    // Un-normalize: A^{1/2} = sqrt(‖A‖)·Y
    let scale = norm.sqrt();
    for v in y.iter_mut() {
        *v *= scale;
    }
    // Residual diagnostics.
    let yy = matmul_sq(&y, &y, n);
    let mut diff = vec![0.0f32; n * n];
    for i in 0..n * n {
        diff[i] = yy[i] - m[i];
    }
    let residual = fro_norm(&diff) / fro_norm(&m).max(1e-12);
    let report = SqrtmReport { iterations, residual, converged: residual < 1e-2 };
    (y, report)
}

/// Tr((A·B)^{1/2}) for SPD A, B — the cross term of FID.
///
/// A·B itself is non-symmetric (Newton–Schulz would diverge on its
/// possibly-indefinite symmetrization), so we use the standard similarity
/// trick: with S = B^{1/2}, Tr((A·B)^{1/2}) = Tr((S·A·S)^{1/2}) and
/// S·A·S is SPD.
pub fn trace_sqrt_product(a: &[f32], b: &[f32], n: usize) -> f32 {
    let (s, _rep) = sqrtm_newton_schulz(b, n, 1e-6, 64);
    let sa = matmul_sq(&s, a, n);
    let sas = matmul_sq(&sa, &s, n);
    let (root, _rep) = sqrtm_newton_schulz(&sas, n, 1e-6, 64);
    trace(&root, n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sqrt_of_identity_is_identity() {
        let i4 = eye(4);
        let (s, rep) = sqrtm_newton_schulz(&i4, 4, 0.0, 32);
        assert!(rep.converged, "residual={}", rep.residual);
        for r in 0..4 {
            for c in 0..4 {
                let want = if r == c { 1.0 } else { 0.0 };
                assert!((s[r * 4 + c] - want).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn sqrt_of_diagonal() {
        let mut a = vec![0.0f32; 9];
        a[0] = 4.0;
        a[4] = 9.0;
        a[8] = 16.0;
        let (s, rep) = sqrtm_newton_schulz(&a, 3, 0.0, 64);
        assert!(rep.converged, "residual={}", rep.residual);
        assert!((s[0] - 2.0).abs() < 1e-2);
        assert!((s[4] - 3.0).abs() < 1e-2);
        assert!((s[8] - 4.0).abs() < 1e-2);
    }

    #[test]
    fn sqrt_squares_back() {
        // Random-ish SPD matrix: A = Bᵀ·B + I
        let b = [0.5f32, -1.0, 2.0, 0.3, 1.0, -0.7, 0.2, 0.9, 1.5];
        let bt = super::super::transpose(&b, 3, 3);
        let mut a = matmul_sq(&bt, &b, 3);
        for i in 0..3 {
            a[i * 3 + i] += 1.0;
        }
        let (s, rep) = sqrtm_newton_schulz(&a, 3, 0.0, 64);
        assert!(rep.converged, "residual={}", rep.residual);
        let ss = matmul_sq(&s, &s, 3);
        for i in 0..9 {
            assert!((ss[i] - a[i]).abs() < 0.05, "i={i} got={} want={}", ss[i], a[i]);
        }
    }

    #[test]
    fn trace_sqrt_product_of_identities() {
        let i3 = eye(3);
        let t = trace_sqrt_product(&i3, &i3, 3);
        assert!((t - 3.0).abs() < 1e-2);
    }
}
