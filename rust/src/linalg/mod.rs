//! Dense linear algebra needed by the metrics stack (FID requires a matrix
//! square root) and the native models: matmul, covariance, trace, and a
//! Newton–Schulz matrix square root.

mod matsqrt;

pub use matsqrt::{sqrtm_newton_schulz, trace_sqrt_product, SqrtmReport};

/// Row-major `m×k · k×n → m×n` with f32 accumulation over a blocked loop.
/// Good enough for metric-sized matrices (≤ a few hundred); the training
/// hot path's matmuls live in XLA.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "A is {m}x{k}");
    assert_eq!(b.len(), k * n, "B is {k}x{n}");
    let mut c = vec![0.0f32; m * n];
    // i-k-j loop order: streams through B rows, C rows stay hot.
    for i in 0..m {
        for p in 0..k {
            let aip = a[i * k + p];
            if aip == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aip * brow[j];
            }
        }
    }
    c
}

/// Transpose an `m×n` row-major matrix.
pub fn transpose(a: &[f32], m: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * n);
    let mut t = vec![0.0f32; n * m];
    for i in 0..m {
        for j in 0..n {
            t[j * m + i] = a[i * n + j];
        }
    }
    t
}

/// Identity matrix n×n.
pub fn eye(n: usize) -> Vec<f32> {
    let mut a = vec![0.0f32; n * n];
    for i in 0..n {
        a[i * n + i] = 1.0;
    }
    a
}

/// Trace of a square matrix.
pub fn trace(a: &[f32], n: usize) -> f32 {
    assert_eq!(a.len(), n * n);
    (0..n).map(|i| a[i * n + i] as f64).sum::<f64>() as f32
}

/// Frobenius norm.
pub fn fro_norm(a: &[f32]) -> f32 {
    a.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32
}

/// Column mean of an `n×d` sample matrix (rows = samples).
pub fn col_mean(x: &[f32], n: usize, d: usize) -> Vec<f32> {
    assert_eq!(x.len(), n * d);
    assert!(n > 0);
    let mut mu = vec![0.0f64; d];
    for i in 0..n {
        for j in 0..d {
            mu[j] += x[i * d + j] as f64;
        }
    }
    mu.iter().map(|&v| (v / n as f64) as f32).collect()
}

/// Sample covariance (divide by n) of an `n×d` matrix, returned `d×d`.
pub fn covariance(x: &[f32], n: usize, d: usize) -> Vec<f32> {
    assert!(n > 0);
    let mu = col_mean(x, n, d);
    let mut cov = vec![0.0f64; d * d];
    for i in 0..n {
        let row = &x[i * d..(i + 1) * d];
        for a in 0..d {
            let da = (row[a] - mu[a]) as f64;
            for b in a..d {
                cov[a * d + b] += da * (row[b] - mu[b]) as f64;
            }
        }
    }
    let mut out = vec![0.0f32; d * d];
    for a in 0..d {
        for b in a..d {
            let v = (cov[a * d + b] / n as f64) as f32;
            out[a * d + b] = v;
            out[b * d + a] = v;
        }
    }
    out
}

/// `C = A·B` for square n×n (convenience wrapper).
pub fn matmul_sq(a: &[f32], b: &[f32], n: usize) -> Vec<f32> {
    matmul(a, b, n, n, n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        // [[1,2],[3,4]] * [[5,6],[7,8]] = [[19,22],[43,50]]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        assert_eq!(matmul(&a, &b, 2, 2, 2), vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_rect() {
        // (1x3)·(3x2)
        let a = [1.0, 2.0, 3.0];
        let b = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        assert_eq!(matmul(&a, &b, 1, 3, 2), vec![4.0, 5.0]);
    }

    #[test]
    fn transpose_round_trip() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2x3
        let t = transpose(&a, 2, 3);
        assert_eq!(t, vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
        assert_eq!(transpose(&t, 3, 2), a.to_vec());
    }

    #[test]
    fn identity_is_neutral() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let i = eye(2);
        assert_eq!(matmul_sq(&a, &i, 2), a.to_vec());
        assert_eq!(matmul_sq(&i, &a, 2), a.to_vec());
        assert_eq!(trace(&i, 2), 2.0);
    }

    #[test]
    fn covariance_of_known_data() {
        // Two vars, perfectly correlated: x2 = 2*x1.
        let x = [1.0, 2.0, 2.0, 4.0, 3.0, 6.0]; // 3 samples x 2 dims
        let cov = covariance(&x, 3, 2);
        // var(x1) = 2/3, cov = 4/3, var(x2) = 8/3
        assert!((cov[0] - 2.0 / 3.0).abs() < 1e-5);
        assert!((cov[1] - 4.0 / 3.0).abs() < 1e-5);
        assert!((cov[3] - 8.0 / 3.0).abs() < 1e-5);
        assert_eq!(cov[1], cov[2]); // symmetric
    }

    #[test]
    fn col_mean_works() {
        let x = [0.0, 10.0, 2.0, 20.0];
        assert_eq!(col_mean(&x, 2, 2), vec![1.0, 15.0]);
    }
}
