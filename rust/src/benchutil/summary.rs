//! Machine-readable bench summaries and trajectory comparison.
//!
//! Two halves:
//!
//! 1. **Emit** — when `DQGAN_BENCH_JSON=PATH` is set, [`Bench::finish`]
//!    calls [`emit_from_env`], which merges this binary's case summaries
//!    into the JSON document at `PATH` (several bench binaries append to
//!    one file across a CI run). The document also records a
//!    **calibration anchor** `calib_ns`: the median time of a fixed
//!    integer workload ([`calibrate_ns`]) measured on the same machine in
//!    the same run. Dividing every case median by the run's anchor gives
//!    a dimensionless cost that transfers across machines far better than
//!    raw nanoseconds.
//!
//! 2. **Compare** — [`compare`] checks a fresh document against a
//!    committed baseline (`BENCH_*.json` at the repo root): any case
//!    whose calibration-normalized median regressed by more than the
//!    noise threshold fails, and every `speedup_gates` entry must show
//!    `<name>/scalar` ÷ `<name>/simd` ≥ the floor in the fresh run. The
//!    CI `bench-compare` job drives this through the
//!    `dqgan bench-compare` subcommand.
//!
//! [`Bench::finish`]: super::Bench::finish

use std::collections::BTreeMap;
use std::time::Instant;

use super::Summary;
use crate::util::json::Json;

/// Schema version stamped into `meta.schema`.
pub const SCHEMA: u64 = 1;

/// Median wall time (ns) of a fixed integer workload — the calibration
/// anchor that makes bench medians comparable across machines. Pure
/// integer LCG mixing: no FP, no memory traffic, no allocator — it
/// tracks core clock speed, which is the dominant cross-machine scale
/// factor for these compute-bound kernels.
pub fn calibrate_ns() -> u64 {
    fn spin() -> u64 {
        let mut x = 0x9E37_79B9_7F4A_7C15u64;
        let mut acc = 0u64;
        for _ in 0..1_000_000 {
            x = x.wrapping_mul(6_364_136_223_846_793_005).wrapping_add(1_442_695_040_888_963_407);
            acc = acc.wrapping_add(x >> 33);
        }
        acc
    }
    let mut samples = [0u64; 9];
    for s in samples.iter_mut() {
        let t = Instant::now();
        super::black_box(spin());
        *s = t.elapsed().as_nanos() as u64;
    }
    samples.sort_unstable();
    samples[samples.len() / 2].max(1)
}

/// One case as a JSON object (`median_ns`, `mean_ns`, `bytes_per_iter`,
/// `threads`).
fn case_json(s: &Summary) -> Json {
    let mut m = BTreeMap::new();
    m.insert("median_ns".to_string(), Json::Num(s.median.as_nanos() as f64));
    m.insert("mean_ns".to_string(), Json::Num(s.mean.as_nanos() as f64));
    if let Some(b) = s.bytes_per_iter {
        m.insert("bytes_per_iter".to_string(), Json::Num(b as f64));
    }
    m.insert("threads".to_string(), Json::Num(s.threads as f64));
    Json::Obj(m)
}

/// Merge `summaries` into the JSON document at `$DQGAN_BENCH_JSON`
/// (creating it if absent), preserving any cases other bench binaries
/// already wrote this run. No-op when the variable is unset.
pub fn emit_from_env(summaries: &[Summary]) -> anyhow::Result<()> {
    let Ok(path) = std::env::var("DQGAN_BENCH_JSON") else {
        return Ok(());
    };
    if path.is_empty() || summaries.is_empty() {
        return Ok(());
    }
    let mut doc = match std::fs::read_to_string(&path) {
        Ok(text) => Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("existing {path} is not valid JSON: {e}"))?,
        Err(_) => Json::Obj(BTreeMap::new()),
    };
    let Json::Obj(root) = &mut doc else {
        anyhow::bail!("existing {path} is not a JSON object");
    };
    // meta: stamp schema + a calibration anchor once per file.
    let meta = root.entry("meta".to_string()).or_insert_with(|| Json::Obj(BTreeMap::new()));
    if let Json::Obj(meta) = meta {
        meta.entry("schema".to_string()).or_insert(Json::Num(SCHEMA as f64));
        meta.entry("calib_ns".to_string())
            .or_insert_with(|| Json::Num(calibrate_ns() as f64));
    }
    let cases = root.entry("cases".to_string()).or_insert_with(|| Json::Obj(BTreeMap::new()));
    let Json::Obj(cases) = cases else {
        anyhow::bail!("{path}: \"cases\" is not an object");
    };
    for s in summaries {
        cases.insert(s.name.clone(), case_json(s));
    }
    std::fs::write(&path, to_pretty(&doc))
        .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
    Ok(())
}

/// Outcome of a baseline-vs-fresh comparison. `regressions` and
/// `gate_failures` are human-readable failure lines; empty ⇔ pass.
#[derive(Debug, Default)]
pub struct Comparison {
    /// One informational line per case compared.
    pub lines: Vec<String>,
    /// Cases whose normalized median regressed past the threshold.
    pub regressions: Vec<String>,
    /// `speedup_gates` entries whose scalar/simd ratio missed the floor.
    pub gate_failures: Vec<String>,
    /// Number of cases present in both documents.
    pub compared: usize,
}

impl Comparison {
    pub fn passed(&self) -> bool {
        self.regressions.is_empty() && self.gate_failures.is_empty()
    }
}

fn median_of(doc: &Json, name: &str) -> Option<f64> {
    doc.get("cases")?.get(name)?.get("median_ns")?.as_f64()
}

fn calib_of(doc: &Json) -> f64 {
    doc.get("meta")
        .and_then(|m| m.get("calib_ns"))
        .and_then(Json::as_f64)
        .filter(|&c| c > 0.0)
        .unwrap_or(1.0)
}

/// Compare `fresh` bench results against a committed `baseline`.
///
/// * **Regression check** — for every case in both documents, medians
///   are divided by their own document's `calib_ns` anchor; fail when
///   `fresh_norm > base_norm · (1 + threshold)`. The threshold absorbs
///   run-to-run noise (CI uses 0.15 = 15%, above the observed jitter of
///   the trimmed medians on shared runners).
/// * **Speedup gates** — for every name in the baseline's
///   `speedup_gates` array, the fresh document must contain
///   `<name>/scalar` and `<name>/simd` with
///   `scalar_median / simd_median ≥ min_speedup`. Gates are checked
///   purely within the fresh run, so no calibration is involved.
pub fn compare(baseline: &Json, fresh: &Json, threshold: f64, min_speedup: f64) -> Comparison {
    let mut rep = Comparison::default();
    let base_calib = calib_of(baseline);
    let fresh_calib = calib_of(fresh);
    let empty = BTreeMap::new();
    let base_cases = baseline
        .get("cases")
        .and_then(Json::as_obj)
        .unwrap_or(&empty);
    for (name, case) in base_cases {
        let Some(b) = case.get("median_ns").and_then(Json::as_f64).filter(|&b| b > 0.0) else {
            continue;
        };
        let Some(f) = median_of(fresh, name).filter(|&f| f > 0.0) else {
            rep.lines.push(format!("  skip  {name:<52} (not in fresh run)"));
            continue;
        };
        rep.compared += 1;
        let (bn, fn_) = (b / base_calib, f / fresh_calib);
        let ratio = fn_ / bn;
        let verdict = if ratio > 1.0 + threshold {
            rep.regressions.push(format!(
                "{name}: normalized median {ratio:.2}× baseline (limit {:.2}×)",
                1.0 + threshold
            ));
            "REGRESS"
        } else {
            "ok"
        };
        let pct = (ratio - 1.0) * 100.0;
        rep.lines
            .push(format!("  {verdict:<7} {name:<52} base {bn:.4}  fresh {fn_:.4}  ({pct:+.1}%)"));
    }
    let gates = baseline.get("speedup_gates").and_then(Json::as_arr).unwrap_or(&[]);
    for gate in gates {
        let Some(name) = gate.as_str() else { continue };
        let scalar = median_of(fresh, &format!("{name}/scalar"));
        let simd = median_of(fresh, &format!("{name}/simd"));
        match (scalar, simd) {
            (Some(s), Some(v)) if v > 0.0 => {
                let speedup = s / v;
                if speedup < min_speedup {
                    rep.gate_failures.push(format!(
                        "{name}: simd speedup {speedup:.2}× < required {min_speedup:.2}×"
                    ));
                } else {
                    rep.lines.push(format!("  gate    {name:<52} simd {speedup:.2}× scalar ✓"));
                }
            }
            _ => rep.gate_failures.push(format!(
                "{name}: fresh run is missing the {name}/scalar and {name}/simd pair"
            )),
        }
    }
    rep
}

/// Small pretty-printer (the compact serializer is unreadable for a
/// committed trajectory file reviewed in diffs).
pub fn to_pretty(v: &Json) -> String {
    let mut out = String::new();
    write_pretty(v, 0, &mut out);
    out.push('\n');
    out
}

fn write_pretty(v: &Json, depth: usize, out: &mut String) {
    const PAD: &str = "  ";
    match v {
        Json::Arr(items) if !items.is_empty() => {
            out.push_str("[\n");
            for (i, item) in items.iter().enumerate() {
                out.push_str(&PAD.repeat(depth + 1));
                write_pretty(item, depth + 1, out);
                if i + 1 < items.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            out.push_str(&PAD.repeat(depth));
            out.push(']');
        }
        Json::Obj(map) if !map.is_empty() => {
            out.push_str("{\n");
            for (i, (k, val)) in map.iter().enumerate() {
                out.push_str(&PAD.repeat(depth + 1));
                out.push_str(&Json::Str(k.clone()).to_string_compact());
                out.push_str(": ");
                write_pretty(val, depth + 1, out);
                if i + 1 < map.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            out.push_str(&PAD.repeat(depth));
            out.push('}');
        }
        other => out.push_str(&other.to_string_compact()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(calib: f64, cases: &[(&str, f64)], gates: &[&str]) -> Json {
        let mut root = BTreeMap::new();
        let mut meta = BTreeMap::new();
        meta.insert("calib_ns".to_string(), Json::Num(calib));
        meta.insert("schema".to_string(), Json::Num(SCHEMA as f64));
        root.insert("meta".to_string(), Json::Obj(meta));
        let mut cs = BTreeMap::new();
        for (name, median) in cases {
            let mut c = BTreeMap::new();
            c.insert("median_ns".to_string(), Json::Num(*median));
            c.insert("threads".to_string(), Json::Num(1.0));
            cs.insert(name.to_string(), Json::Obj(c));
        }
        root.insert("cases".to_string(), Json::Obj(cs));
        root.insert(
            "speedup_gates".to_string(),
            Json::Arr(gates.iter().map(|g| Json::Str(g.to_string())).collect()),
        );
        Json::Obj(root)
    }

    #[test]
    fn identical_runs_pass() {
        let base = doc(1000.0, &[("g/a", 500.0), ("g/b", 900.0)], &[]);
        let rep = compare(&base, &base, 0.15, 1.5);
        assert!(rep.passed(), "{:?}", rep.regressions);
        assert_eq!(rep.compared, 2);
    }

    #[test]
    fn regression_past_threshold_fails() {
        let base = doc(1000.0, &[("g/a", 500.0)], &[]);
        let fresh = doc(1000.0, &[("g/a", 600.0)], &[]);
        let rep = compare(&base, &fresh, 0.15, 1.5);
        assert_eq!(rep.regressions.len(), 1, "{:?}", rep.lines);
        // Within the threshold: passes.
        let ok = doc(1000.0, &[("g/a", 560.0)], &[]);
        assert!(compare(&base, &ok, 0.15, 1.5).passed());
    }

    #[test]
    fn calibration_normalizes_machine_speed() {
        // Fresh machine is 2× slower (calib 2000 vs 1000) and the case
        // took 2× longer in raw ns — normalized, that's no regression.
        let base = doc(1000.0, &[("g/a", 500.0)], &[]);
        let fresh = doc(2000.0, &[("g/a", 1000.0)], &[]);
        assert!(compare(&base, &fresh, 0.15, 1.5).passed());
        // Same raw time on the slower machine is a (normalized) win.
        let faster = doc(2000.0, &[("g/a", 500.0)], &[]);
        assert!(compare(&base, &faster, 0.15, 1.5).passed());
    }

    #[test]
    fn speedup_gate_checks_fresh_pair() {
        let base = doc(1000.0, &[], &["g/fold"]);
        let good = doc(1000.0, &[("g/fold/scalar", 900.0), ("g/fold/simd", 300.0)], &[]);
        assert!(compare(&base, &good, 0.15, 1.5).passed());
        let slow = doc(1000.0, &[("g/fold/scalar", 900.0), ("g/fold/simd", 800.0)], &[]);
        let rep = compare(&base, &slow, 0.15, 1.5);
        assert_eq!(rep.gate_failures.len(), 1);
        // Pair missing entirely: also a gate failure, not a silent pass.
        let missing = doc(1000.0, &[], &[]);
        assert_eq!(compare(&base, &missing, 0.15, 1.5).gate_failures.len(), 1);
    }

    #[test]
    fn missing_case_is_skipped_not_failed() {
        let base = doc(1000.0, &[("g/a", 500.0), ("g/gone", 100.0)], &[]);
        let fresh = doc(1000.0, &[("g/a", 500.0)], &[]);
        let rep = compare(&base, &fresh, 0.15, 1.5);
        assert!(rep.passed());
        assert_eq!(rep.compared, 1);
    }

    #[test]
    fn pretty_round_trips() {
        let base = doc(1000.0, &[("g/a", 500.0)], &["g/fold"]);
        let text = to_pretty(&base);
        assert_eq!(Json::parse(&text).unwrap(), base);
        assert!(text.contains("\n"), "actually pretty: {text}");
    }

    #[test]
    fn calibration_anchor_is_positive() {
        assert!(calibrate_ns() > 0);
    }

    #[test]
    fn emit_merges_into_existing_file() {
        let dir = std::env::temp_dir().join(format!("dqgan-bench-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("summary.json");
        let _ = std::fs::remove_file(&path);
        std::env::set_var("DQGAN_BENCH_JSON", &path);
        let s1 = Summary {
            name: "g/a".into(),
            iters: 1,
            mean: std::time::Duration::from_nanos(120),
            median: std::time::Duration::from_nanos(100),
            p95: std::time::Duration::from_nanos(130),
            min: std::time::Duration::from_nanos(90),
            bytes_per_iter: Some(64),
            threads: 2,
        };
        emit_from_env(&[s1.clone()]).unwrap();
        let mut s2 = s1.clone();
        s2.name = "g/b".into();
        emit_from_env(&[s2]).unwrap();
        std::env::remove_var("DQGAN_BENCH_JSON");
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(median_of(&doc, "g/a"), Some(100.0));
        assert_eq!(median_of(&doc, "g/b"), Some(100.0));
        let threads = doc.get("cases").unwrap().get("g/a").unwrap().get("threads").unwrap();
        assert_eq!(threads.as_usize(), Some(2));
        assert!(calib_of(&doc) > 0.0);
        let _ = std::fs::remove_file(&path);
    }
}
