//! Mini benchmarking harness (criterion is unavailable offline).
//!
//! Provides warmup, adaptive iteration counts targeting a fixed measurement
//! time, outlier-trimmed statistics, and throughput reporting. Used by all
//! `[[bench]] harness = false` targets:
//!
//! ```ignore
//! let mut b = Bench::new("quantizers");
//! b.bench_with_throughput("qsgd/1M", bytes, || quantize(&v));
//! b.finish();
//! ```
//!
//! Environment knobs: `DQGAN_BENCH_MS` (per-case measurement budget,
//! default 300 ms), `DQGAN_BENCH_WARMUP_MS` (default 100 ms),
//! `DQGAN_BENCH_FILTER` (substring filter on case names).

pub mod summary;

use std::hint::black_box as bb;
use std::time::{Duration, Instant};

/// Re-exported so benches can `benchutil::black_box` without `std::hint`.
pub fn black_box<T>(x: T) -> T {
    bb(x)
}

/// Trimmed summary of one benchmark case.
#[derive(Debug, Clone)]
pub struct Summary {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub min: Duration,
    /// Bytes processed per iteration, if provided (for throughput).
    pub bytes_per_iter: Option<u64>,
    /// Worker threads the case runs on (1 = single-threaded); recorded
    /// in the machine-readable summary so trajectories aren't compared
    /// across different parallelism.
    pub threads: usize,
}

impl Summary {
    /// MB/s based on mean time, if bytes were provided.
    pub fn throughput_mbs(&self) -> Option<f64> {
        self.bytes_per_iter.map(|b| b as f64 / self.mean.as_secs_f64() / 1e6)
    }
}

fn env_ms(key: &str, default_ms: u64) -> Duration {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .map(Duration::from_millis)
        .unwrap_or(Duration::from_millis(default_ms))
}

/// A group of benchmark cases with shared reporting.
pub struct Bench {
    group: String,
    measure_budget: Duration,
    warmup_budget: Duration,
    filter: Option<String>,
    threads: usize,
    results: Vec<Summary>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        Self {
            group: group.to_string(),
            measure_budget: env_ms("DQGAN_BENCH_MS", 300),
            warmup_budget: env_ms("DQGAN_BENCH_WARMUP_MS", 100),
            filter: std::env::var("DQGAN_BENCH_FILTER").ok(),
            threads: 1,
            results: Vec::new(),
        }
    }

    /// Record subsequent cases as running on `threads` worker threads
    /// (metadata only — the harness never spawns threads itself).
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    /// Override the per-case budgets (for expensive end-to-end cases).
    pub fn with_budget(mut self, measure: Duration, warmup: Duration) -> Self {
        self.measure_budget = measure;
        self.warmup_budget = warmup;
        self
    }

    fn skip(&self, name: &str) -> bool {
        match &self.filter {
            Some(f) => !name.contains(f.as_str()),
            None => false,
        }
    }

    /// Benchmark a closure.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> Option<&Summary> {
        self.bench_inner(name, None, &mut || {
            bb(f());
        })
    }

    /// Benchmark a closure that processes `bytes` per call (throughput).
    pub fn bench_with_throughput<T>(
        &mut self,
        name: &str,
        bytes: u64,
        mut f: impl FnMut() -> T,
    ) -> Option<&Summary> {
        self.bench_inner(name, Some(bytes), &mut || {
            bb(f());
        })
    }

    fn bench_inner(
        &mut self,
        name: &str,
        bytes: Option<u64>,
        f: &mut dyn FnMut(),
    ) -> Option<&Summary> {
        if self.skip(name) {
            return None;
        }
        // Warmup + calibration: how long does one call take?
        let warm_start = Instant::now();
        let mut calib_iters: u64 = 0;
        while warm_start.elapsed() < self.warmup_budget || calib_iters == 0 {
            f();
            calib_iters += 1;
            if calib_iters >= 1_000_000 {
                break;
            }
        }
        let per_call = warm_start.elapsed().as_secs_f64() / calib_iters as f64;
        // Sample in batches so timer overhead is amortized; aim for ~50
        // samples within the measurement budget.
        let target_samples = 50usize;
        let batch = ((self.measure_budget.as_secs_f64() / target_samples as f64 / per_call)
            .ceil() as u64)
            .max(1);
        let mut samples: Vec<Duration> = Vec::with_capacity(target_samples);
        let meas_start = Instant::now();
        let mut total_iters = 0u64;
        while meas_start.elapsed() < self.measure_budget && samples.len() < 10_000 {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t.elapsed() / batch as u32);
            total_iters += batch;
        }
        samples.sort();
        // Trim top/bottom 5%.
        let trim = samples.len() / 20;
        let trimmed = &samples[trim..samples.len() - trim.min(samples.len() - 1)];
        let mean_nanos =
            trimmed.iter().map(|d| d.as_nanos() as f64).sum::<f64>() / trimmed.len() as f64;
        let summary = Summary {
            name: format!("{}/{}", self.group, name),
            iters: total_iters,
            mean: Duration::from_nanos(mean_nanos as u64),
            median: trimmed[trimmed.len() / 2],
            p95: trimmed[(trimmed.len() as f64 * 0.95) as usize % trimmed.len()],
            min: *samples.first().unwrap(),
            bytes_per_iter: bytes,
            threads: self.threads,
        };
        print_summary(&summary);
        self.results.push(summary);
        self.results.last()
    }

    /// Print the final table; call at the end of the bench binary. Also
    /// merges the machine-readable summary into `$DQGAN_BENCH_JSON` when
    /// set (see [`summary::emit_from_env`]).
    pub fn finish(self) -> Vec<Summary> {
        eprintln!("\n== {} ({} cases) ==", self.group, self.results.len());
        if let Err(e) = summary::emit_from_env(&self.results) {
            eprintln!("warning: bench summary not written: {e}");
        }
        self.results
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

fn print_summary(s: &Summary) {
    let tp = match s.throughput_mbs() {
        Some(mbs) if mbs >= 1000.0 => format!("  [{:.2} GB/s]", mbs / 1000.0),
        Some(mbs) => format!("  [{mbs:.1} MB/s]"),
        None => String::new(),
    };
    println!(
        "{:<52} mean {:>10}  median {:>10}  p95 {:>10}  min {:>10}  ({} iters){tp}",
        s.name,
        fmt_dur(s.mean),
        fmt_dur(s.median),
        fmt_dur(s.p95),
        fmt_dur(s.min),
        s.iters,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_numbers() {
        std::env::set_var("DQGAN_BENCH_MS", "20");
        std::env::set_var("DQGAN_BENCH_WARMUP_MS", "5");
        let mut b = Bench::new("test");
        let mut acc = 0u64;
        let s = b
            .bench("noop-ish", || {
                acc = acc.wrapping_add(1);
                acc
            })
            .unwrap()
            .clone();
        assert!(s.iters > 0);
        assert!(s.mean.as_nanos() > 0);
        assert!(s.min <= s.median);
        std::env::remove_var("DQGAN_BENCH_MS");
        std::env::remove_var("DQGAN_BENCH_WARMUP_MS");
    }

    #[test]
    fn throughput_is_computed() {
        std::env::set_var("DQGAN_BENCH_MS", "10");
        std::env::set_var("DQGAN_BENCH_WARMUP_MS", "2");
        let data = vec![1.0f32; 1024];
        let mut b = Bench::new("test");
        let s = b
            .bench_with_throughput("sum", (data.len() * 4) as u64, || {
                data.iter().sum::<f32>()
            })
            .unwrap()
            .clone();
        assert!(s.throughput_mbs().unwrap() > 0.0);
        std::env::remove_var("DQGAN_BENCH_MS");
        std::env::remove_var("DQGAN_BENCH_WARMUP_MS");
    }
}
