//! Fixed random-feature conv net — the Inception-v3 stand-in.
//!
//! Architecture (CHW, input 3×32×32, pixels in [−1,1]):
//!
//! ```text
//! conv1: 3→12, 3×3, stride 2, pad 1 → ReLU   (12×16×16)
//! conv2: 12→32, 3×3, stride 2, pad 1 → ReLU  (32×8×8)
//! global average pool → features ∈ R³²
//! head: linear 32→10 → logits (for the proxy Inception Score)
//! ```
//!
//! All weights are drawn once from a **fixed seed** (He-scaled Gaussians),
//! so every run, every method and both language implementations (this one
//! and `python/compile/models/feature_net.py`) score with the *same*
//! embedding.

use crate::data::{IMG_C, IMG_H, IMG_LEN, IMG_W};
use crate::util::rng::Pcg32;

pub const FEATURE_DIM: usize = 32;
pub const NUM_CLASSES: usize = 10;

const C1: usize = 12;
const C2: usize = FEATURE_DIM;
const K: usize = 3;

/// The canonical seed used by both implementations. Keep in sync with
/// `feature_net.py`.
pub const FEATURE_NET_SEED: u64 = 0xFEA7_0001;

/// Fixed random conv feature extractor.
pub struct FeatureNet {
    /// conv1 [C1][IMG_C][K][K]
    w1: Vec<f32>,
    b1: Vec<f32>,
    /// conv2 [C2][C1][K][K]
    w2: Vec<f32>,
    b2: Vec<f32>,
    /// head [NUM_CLASSES][FEATURE_DIM]
    wh: Vec<f32>,
    bh: Vec<f32>,
}

impl Default for FeatureNet {
    fn default() -> Self {
        Self::new()
    }
}

impl FeatureNet {
    /// Build with the canonical seed.
    pub fn new() -> Self {
        Self::with_seed(FEATURE_NET_SEED)
    }

    /// Build with an explicit seed (tests).
    pub fn with_seed(seed: u64) -> Self {
        let mut rng = Pcg32::new(seed);
        let he = |fan_in: usize, rng: &mut Pcg32| (2.0 / fan_in as f32).sqrt() * rng.normal();
        let w1: Vec<f32> =
            (0..C1 * IMG_C * K * K).map(|_| he(IMG_C * K * K, &mut rng)).collect();
        let b1 = vec![0.0; C1];
        let w2: Vec<f32> = (0..C2 * C1 * K * K).map(|_| he(C1 * K * K, &mut rng)).collect();
        let b2 = vec![0.0; C2];
        let wh: Vec<f32> =
            (0..NUM_CLASSES * FEATURE_DIM).map(|_| he(FEATURE_DIM, &mut rng)).collect();
        let bh = vec![0.0; NUM_CLASSES];
        Self { w1, b1, w2, b2, wh, bh }
    }

    /// Raw weights (exported for the JAX mirror's golden test).
    pub fn weights(&self) -> (&[f32], &[f32], &[f32], &[f32], &[f32], &[f32]) {
        (&self.w1, &self.b1, &self.w2, &self.b2, &self.wh, &self.bh)
    }

    /// Features + logits for one image (flat CHW, length IMG_LEN).
    pub fn features(&self, img: &[f32]) -> (Vec<f32>, Vec<f32>) {
        assert_eq!(img.len(), IMG_LEN);
        // conv1: stride 2, pad 1 → 16×16
        let h1 = IMG_H / 2;
        let w1s = IMG_W / 2;
        let mut a1 = vec![0.0f32; C1 * h1 * w1s];
        conv2d(img, IMG_C, IMG_H, IMG_W, &self.w1, &self.b1, C1, 2, &mut a1);
        relu(&mut a1);
        // conv2: stride 2, pad 1 → 8×8
        let h2 = h1 / 2;
        let w2s = w1s / 2;
        let mut a2 = vec![0.0f32; C2 * h2 * w2s];
        conv2d(&a1, C1, h1, w1s, &self.w2, &self.b2, C2, 2, &mut a2);
        relu(&mut a2);
        // global average pool
        let mut feat = vec![0.0f32; FEATURE_DIM];
        let hw = h2 * w2s;
        for c in 0..C2 {
            let s: f32 = a2[c * hw..(c + 1) * hw].iter().sum();
            feat[c] = s / hw as f32;
        }
        // head
        let mut logits = vec![0.0f32; NUM_CLASSES];
        for k in 0..NUM_CLASSES {
            let mut a = self.bh[k];
            for c in 0..FEATURE_DIM {
                a += self.wh[k * FEATURE_DIM + c] * feat[c];
            }
            logits[k] = a;
        }
        (feat, logits)
    }

    /// Features + logits for a batch (flat n×IMG_LEN). Returns
    /// (features n×FEATURE_DIM, logits n×NUM_CLASSES).
    pub fn features_batch(&self, imgs: &[f32]) -> (Vec<f32>, Vec<f32>) {
        assert_eq!(imgs.len() % IMG_LEN, 0);
        let n = imgs.len() / IMG_LEN;
        let mut feats = Vec::with_capacity(n * FEATURE_DIM);
        let mut logits = Vec::with_capacity(n * NUM_CLASSES);
        for i in 0..n {
            let (f, l) = self.features(&imgs[i * IMG_LEN..(i + 1) * IMG_LEN]);
            feats.extend(f);
            logits.extend(l);
        }
        (feats, logits)
    }
}

/// Direct 3×3 conv, stride `s`, pad 1, CHW layout.
#[allow(clippy::too_many_arguments)]
fn conv2d(
    input: &[f32],
    in_c: usize,
    in_h: usize,
    in_w: usize,
    weight: &[f32],
    bias: &[f32],
    out_c: usize,
    stride: usize,
    out: &mut [f32],
) {
    let out_h = in_h / stride;
    let out_w = in_w / stride;
    assert_eq!(out.len(), out_c * out_h * out_w);
    for oc in 0..out_c {
        for oy in 0..out_h {
            for ox in 0..out_w {
                let mut acc = bias[oc];
                // input center = (oy*stride, ox*stride) with pad 1 means
                // receptive field rows iy = oy*s − 1 + ky.
                for ic in 0..in_c {
                    for ky in 0..K {
                        let iy = (oy * stride + ky) as isize - 1;
                        if iy < 0 || iy >= in_h as isize {
                            continue;
                        }
                        for kx in 0..K {
                            let ix = (ox * stride + kx) as isize - 1;
                            if ix < 0 || ix >= in_w as isize {
                                continue;
                            }
                            let wv = weight
                                [oc * in_c * K * K + ic * K * K + ky * K + kx];
                            let iv = input[ic * in_h * in_w
                                + iy as usize * in_w
                                + ix as usize];
                            acc += wv * iv;
                        }
                    }
                }
                out[oc * out_h * out_w + oy * out_w + ox] = acc;
            }
        }
    }
}

fn relu(a: &mut [f32]) {
    for v in a.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthImages;

    #[test]
    fn deterministic_across_instances() {
        let ds = SynthImages::cifar_like(1);
        let mut rng = Pcg32::new(2);
        let (img, _) = ds.sample_batch(1, &mut rng);
        let n1 = FeatureNet::new();
        let n2 = FeatureNet::new();
        assert_eq!(n1.features(&img).0, n2.features(&img).0);
    }

    #[test]
    fn different_images_different_features() {
        let ds = SynthImages::cifar_like(1);
        let mut rng = Pcg32::new(3);
        let (imgs, _) = ds.sample_batch(2, &mut rng);
        let net = FeatureNet::new();
        let (f, _) = net.features_batch(&imgs);
        let a = &f[..FEATURE_DIM];
        let b = &f[FEATURE_DIM..];
        assert!(crate::util::stats::dist2_sq(a, b) > 1e-6);
    }

    #[test]
    fn features_separate_classes_better_than_chance() {
        // Same-class feature distance < cross-class distance on average —
        // the property that makes proxy-FID discriminative.
        let ds = SynthImages::cifar_like(7);
        let net = FeatureNet::new();
        let mut rng = Pcg32::new(8);
        let mut intra = 0.0f64;
        let mut inter = 0.0f64;
        let mut buf_a = vec![0.0; IMG_LEN];
        let mut buf_b = vec![0.0; IMG_LEN];
        for t in 0..24 {
            let ca = t % 5;
            ds.render(ca, &mut rng, &mut buf_a);
            ds.render(ca, &mut rng, &mut buf_b);
            let fa = net.features(&buf_a).0;
            let fb = net.features(&buf_b).0;
            intra += crate::util::stats::dist2_sq(&fa, &fb) as f64;
            ds.render(ca + 5, &mut rng, &mut buf_b);
            let fc = net.features(&buf_b).0;
            inter += crate::util::stats::dist2_sq(&fa, &fc) as f64;
        }
        assert!(inter > intra, "intra={intra} inter={inter}");
    }

    #[test]
    fn logits_have_class_signal() {
        // Mean logit vectors of two classes should differ.
        let ds = SynthImages::cifar_like(9);
        let net = FeatureNet::new();
        let mut rng = Pcg32::new(10);
        let mut buf = vec![0.0; IMG_LEN];
        let mean_logits = |cls: usize, rng: &mut Pcg32, buf: &mut Vec<f32>| {
            let mut acc = vec![0.0f32; NUM_CLASSES];
            for _ in 0..10 {
                ds.render(cls, rng, buf);
                let (_, l) = net.features(buf);
                for (a, b) in acc.iter_mut().zip(&l) {
                    *a += b / 10.0;
                }
            }
            acc
        };
        let l0 = mean_logits(0, &mut rng, &mut buf);
        let l1 = mean_logits(1, &mut rng, &mut buf);
        assert!(crate::util::stats::dist2_sq(&l0, &l1) > 1e-4);
    }
}
