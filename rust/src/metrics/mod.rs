//! Evaluation metrics (paper §4): proxy Inception Score and proxy FID over
//! a fixed random-feature convolutional network.
//!
//! The paper scores CIFAR-10/CelebA GANs with the Inception-v3 network;
//! offline we substitute a *fixed, seeded* random conv net (DESIGN.md §5):
//! IS/FID are functionals of a fixed feature map, and comparisons between
//! methods trained on the same data are preserved under any sufficiently
//! nonlinear fixed embedding. The same network ships as a JAX artifact
//! (`python/compile/models/feature_net.py`); an integration test checks
//! the two implementations agree.

mod feature_net;
mod fid;
mod inception_proxy;

pub use feature_net::{FeatureNet, FEATURE_DIM, NUM_CLASSES};
pub use fid::{fid_from_features, FidParts};
pub use inception_proxy::inception_score;

use crate::data::IMG_LEN;
use crate::util::rng::Pcg32;

/// Score a batch of images (flat n×IMG_LEN, CHW, [−1,1]) against a batch
/// of reference images: returns (inception-proxy score, proxy FID).
pub fn score_images(
    net: &FeatureNet,
    generated: &[f32],
    reference: &[f32],
) -> (f32, f32) {
    let n_gen = generated.len() / IMG_LEN;
    let n_ref = reference.len() / IMG_LEN;
    assert!(n_gen > 1 && n_ref > 1, "need ≥2 images on each side");
    let (feat_g, logits_g) = net.features_batch(generated);
    let (feat_r, _) = net.features_batch(reference);
    let is = inception_score(&logits_g, n_gen);
    let fid = fid_from_features(&feat_g, n_gen, &feat_r, n_ref, FEATURE_DIM).fid;
    (is, fid)
}

/// Convenience for tests: render a labelled reference batch.
pub fn reference_batch(
    ds: &crate::data::SynthImages,
    n: usize,
    rng: &mut Pcg32,
) -> Vec<f32> {
    ds.sample_batch(n, rng).0
}
