//! Proxy Fréchet Inception Distance (Dowson & Landau [8]):
//!
//!   FID = ‖μ₁ − μ₂‖² + Tr(Σ₁ + Σ₂ − 2·(Σ₁Σ₂)^{1/2})
//!
//! computed over the fixed feature net's pooled features, with the matrix
//! square root from `linalg::sqrtm_newton_schulz`. Lower is better.

use crate::linalg::{col_mean, covariance, trace, trace_sqrt_product};
use crate::util::stats::dist2_sq;

/// FID plus its decomposition (useful for diagnostics/tests).
#[derive(Debug, Clone)]
pub struct FidParts {
    pub fid: f32,
    pub mean_term: f32,
    pub cov_term: f32,
}

/// FID between two feature batches (flat n×d each, rows = samples).
pub fn fid_from_features(
    feat_a: &[f32],
    n_a: usize,
    feat_b: &[f32],
    n_b: usize,
    d: usize,
) -> FidParts {
    assert_eq!(feat_a.len(), n_a * d);
    assert_eq!(feat_b.len(), n_b * d);
    assert!(n_a > 1 && n_b > 1, "need ≥ 2 samples per side for covariance");
    let mu_a = col_mean(feat_a, n_a, d);
    let mu_b = col_mean(feat_b, n_b, d);
    let cov_a = covariance(feat_a, n_a, d);
    let cov_b = covariance(feat_b, n_b, d);
    let mean_term = dist2_sq(&mu_a, &mu_b);
    let tr_a = trace(&cov_a, d);
    let tr_b = trace(&cov_b, d);
    let tr_cross = trace_sqrt_product(&cov_a, &cov_b, d);
    // Clamp: the cross term can exceed (tr_a+tr_b)/2 only through numeric
    // error; FID is non-negative by construction.
    let cov_term = (tr_a + tr_b - 2.0 * tr_cross).max(0.0);
    FidParts { fid: mean_term + cov_term, mean_term, cov_term }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn gaussian_features(n: usize, d: usize, mean: f32, std: f32, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::new(seed);
        (0..n * d).map(|_| mean + std * rng.normal()).collect()
    }

    #[test]
    fn identical_distributions_give_near_zero() {
        let a = gaussian_features(2000, 8, 0.0, 1.0, 1);
        let b = gaussian_features(2000, 8, 0.0, 1.0, 2);
        let parts = fid_from_features(&a, 2000, &b, 2000, 8);
        assert!(parts.fid < 0.15, "fid={}", parts.fid);
    }

    #[test]
    fn mean_shift_shows_up_quadratically() {
        let a = gaussian_features(2000, 4, 0.0, 1.0, 3);
        let b1 = gaussian_features(2000, 4, 1.0, 1.0, 4);
        let b2 = gaussian_features(2000, 4, 2.0, 1.0, 5);
        let f1 = fid_from_features(&a, 2000, &b1, 2000, 4).fid;
        let f2 = fid_from_features(&a, 2000, &b2, 2000, 4).fid;
        // ‖μdiff‖² scales 4×: shift 1 → ≈4, shift 2 → ≈16 (d=4 dims each
        // shifted by 1 resp. 2: 4·1=4 vs 4·4=16).
        assert!((f1 - 4.0).abs() < 0.8, "f1={f1}");
        assert!((f2 - 16.0).abs() < 2.0, "f2={f2}");
    }

    #[test]
    fn variance_mismatch_is_detected() {
        let a = gaussian_features(3000, 4, 0.0, 1.0, 6);
        let b = gaussian_features(3000, 4, 0.0, 2.0, 7);
        let parts = fid_from_features(&a, 3000, &b, 3000, 4);
        // per dim: 1 + 4 − 2·√(1·4) = 1 → total ≈ d = 4.
        assert!((parts.cov_term - 4.0).abs() < 0.8, "cov_term={}", parts.cov_term);
        assert!(parts.mean_term < 0.2);
    }

    #[test]
    fn fid_is_symmetric_enough() {
        let a = gaussian_features(1000, 6, 0.0, 1.0, 8);
        let b = gaussian_features(1000, 6, 0.5, 1.5, 9);
        let f_ab = fid_from_features(&a, 1000, &b, 1000, 6).fid;
        let f_ba = fid_from_features(&b, 1000, &a, 1000, 6).fid;
        assert!((f_ab - f_ba).abs() < 0.05 * f_ab.max(1.0), "{f_ab} vs {f_ba}");
    }
}
