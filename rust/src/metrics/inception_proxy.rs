//! Proxy Inception Score (Salimans et al. [38]) over the fixed feature
//! net's classifier head:
//!
//!   IS = exp( E_x[ KL( p(y|x) ‖ p(y) ) ] ),   p(y) = E_x[ p(y|x) ]
//!
//! Higher is better: it rewards confident per-sample predictions (quality)
//! spread across many classes (diversity). Range is [1, NUM_CLASSES].

use super::NUM_CLASSES;

/// Softmax in place (numerically stable).
fn softmax(logits: &mut [f32]) {
    let max = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0.0f32;
    for v in logits.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    for v in logits.iter_mut() {
        *v /= sum;
    }
}

/// Inception score from a flat [n × NUM_CLASSES] logits buffer.
pub fn inception_score(logits: &[f32], n: usize) -> f32 {
    assert_eq!(logits.len(), n * NUM_CLASSES);
    assert!(n > 0);
    // per-sample p(y|x) and the marginal p(y)
    let mut probs = logits.to_vec();
    let mut marginal = vec![0.0f64; NUM_CLASSES];
    for i in 0..n {
        let row = &mut probs[i * NUM_CLASSES..(i + 1) * NUM_CLASSES];
        softmax(row);
        for (m, &p) in marginal.iter_mut().zip(row.iter()) {
            *m += p as f64 / n as f64;
        }
    }
    // E KL(p(y|x) || p(y))
    let mut kl = 0.0f64;
    for i in 0..n {
        let row = &probs[i * NUM_CLASSES..(i + 1) * NUM_CLASSES];
        for (k, &p) in row.iter().enumerate() {
            if p > 1e-12 {
                kl += p as f64 * ((p as f64 / marginal[k].max(1e-12)).ln()) / n as f64;
            }
        }
    }
    kl.exp() as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_hot_logits(class: usize, sharp: f32) -> Vec<f32> {
        let mut l = vec![0.0f32; NUM_CLASSES];
        l[class] = sharp;
        l
    }

    #[test]
    fn uniform_predictions_give_score_one() {
        // All samples predicted uniformly → KL = 0 → IS = 1.
        let n = 16;
        let logits = vec![0.0f32; n * NUM_CLASSES];
        let is = inception_score(&logits, n);
        assert!((is - 1.0).abs() < 1e-4, "is={is}");
    }

    #[test]
    fn confident_diverse_predictions_max_score() {
        // Each sample confidently in a distinct class, all classes covered:
        // IS → NUM_CLASSES.
        let n = NUM_CLASSES;
        let mut logits = Vec::new();
        for c in 0..n {
            logits.extend(one_hot_logits(c, 50.0));
        }
        let is = inception_score(&logits, n);
        assert!(is > NUM_CLASSES as f32 * 0.95, "is={is}");
    }

    #[test]
    fn mode_collapse_scores_low() {
        // All samples confidently the SAME class → p(y) = p(y|x) → IS = 1.
        let n = 32;
        let mut logits = Vec::new();
        for _ in 0..n {
            logits.extend(one_hot_logits(3, 50.0));
        }
        let is = inception_score(&logits, n);
        assert!((is - 1.0).abs() < 1e-3, "is={is}");
    }

    #[test]
    fn partial_coverage_is_intermediate() {
        // Confident predictions over half the classes: IS ≈ NUM_CLASSES/2.
        let n = NUM_CLASSES;
        let mut logits = Vec::new();
        for c in 0..n {
            logits.extend(one_hot_logits(c % (NUM_CLASSES / 2), 50.0));
        }
        let is = inception_score(&logits, n);
        assert!(
            (is - (NUM_CLASSES / 2) as f32).abs() < 0.5,
            "is={is}, want ≈ {}",
            NUM_CLASSES / 2
        );
    }
}
