//! PERF: server-side aggregation (q̄ = 1/M Σ p̂) and the hot vector ops of
//! the worker loop — the L3 costs that must not dominate round time.
//!
//! The headline case is the sequential-vs-sharded-vs-streaming leader A/B
//! over real 8-bit linf wire payloads at DCGAN dimension: the sharded
//! [`dqgan::ps::Aggregator`] must beat the sequential baseline at M ≥ 8
//! on a multi-core host (decode is worker-parallel, the reduce is
//! shard-parallel, and all modes produce bitwise-identical averages — see
//! `tests/integration_aggregate.rs`). This file measures pure compute
//! with all payloads already in hand; `bench_streaming.rs` measures the
//! streaming engine's overlap win under *skewed arrivals*, which is where
//! decode-on-arrival actually pays.

use dqgan::benchutil::Bench;
use dqgan::comm::Message;
use dqgan::compress::{compressor_from_spec, Compressor};
use dqgan::config::{AggMode, AggregatorConfig};
use dqgan::ps::{Aggregator, Decoder};
use dqgan::tensor::ops;
use dqgan::util::rng::Pcg32;
use std::sync::Arc;

fn main() {
    let mut b = Bench::new("aggregation");
    let mut rng = Pcg32::new(5);
    let d = 400_708usize; // DCGAN dim

    // End-to-end leader path: decode M × linf8 payloads + average.
    let codec = compressor_from_spec("linf8").unwrap();
    let decoder: Decoder = {
        let c = compressor_from_spec("linf8").unwrap();
        Arc::new(move |bytes: &[u8], out: &mut [f32]| c.decode_into(bytes, out))
    };
    for &m in &[4usize, 8, 32] {
        let msgs: Vec<Message> = (0..m)
            .map(|w| {
                let v = rng.normal_vec(d);
                let mut wire = Vec::new();
                codec.compress_encoded(&v, &mut rng, &mut wire);
                Message::payload(w as u32, 0, wire)
            })
            .collect();
        for mode in [AggMode::Sequential, AggMode::Sharded, AggMode::Streaming] {
            let mut agg =
                Aggregator::new(AggregatorConfig { mode, ..Default::default() }, d, m);
            let tag = match mode {
                AggMode::Sequential => "sequential",
                AggMode::Sharded => "sharded",
                AggMode::Streaming => "streaming",
                // Not in this A/B's mode list (downlink-side change; see
                // benches/bench_pipeline.rs).
                AggMode::Pipelined => "pipelined",
            };
            b.bench_with_throughput(
                &format!("decode+average/{tag}/M={m}/d={d}"),
                (4 * d * m) as u64,
                || agg.aggregate(0, &msgs, &decoder).unwrap()[0],
            );
        }
    }

    // Reduce-only cost (pre-decoded dense payloads).
    for &m in &[4usize, 8, 32] {
        let payloads: Vec<Vec<f32>> = (0..m).map(|_| rng.normal_vec(d)).collect();
        let refs: Vec<&[f32]> = payloads.iter().map(|p| p.as_slice()).collect();
        let mut out = vec![0.0f32; d];
        b.bench_with_throughput(&format!("mean_into/M={m}/d={d}"), (4 * d * m) as u64, || {
            ops::mean_into(&refs, &mut out);
            out[0]
        });
    }

    // Worker-side fused ops.
    let x = rng.normal_vec(d);
    let e = rng.normal_vec(d);
    let mut out = vec![0.0f32; d];
    b.bench_with_throughput(&format!("scaled_add(p=etaF+e)/d={d}"), (4 * d) as u64, || {
        ops::scaled_add(0.01, &x, &e, &mut out);
        out[0]
    });
    let mut w = rng.normal_vec(d);
    b.bench_with_throughput(&format!("axpy/d={d}"), (4 * d) as u64, || {
        ops::axpy(-0.01, &x, &mut w);
        w[0]
    });
    b.bench_with_throughput(&format!("all_finite/d={d}"), (4 * d) as u64, || {
        ops::all_finite(&x)
    });
    b.finish();
}
