//! PERF: server-side aggregation (q̄ = 1/M Σ p̂) and the hot vector ops of
//! the worker loop — the L3 costs that must not dominate round time.

use dqgan::benchutil::Bench;
use dqgan::tensor::ops;
use dqgan::util::rng::Pcg32;

fn main() {
    let mut b = Bench::new("aggregation");
    let mut rng = Pcg32::new(5);
    let d = 400_708usize; // DCGAN dim
    for &m in &[4usize, 8, 32] {
        let payloads: Vec<Vec<f32>> = (0..m).map(|_| rng.normal_vec(d)).collect();
        let refs: Vec<&[f32]> = payloads.iter().map(|p| p.as_slice()).collect();
        let mut out = vec![0.0f32; d];
        b.bench_with_throughput(&format!("mean_into/M={m}/d={d}"), (4 * d * m) as u64, || {
            ops::mean_into(&refs, &mut out);
            out[0]
        });
    }
    // Worker-side fused ops.
    let x = rng.normal_vec(d);
    let e = rng.normal_vec(d);
    let mut out = vec![0.0f32; d];
    b.bench_with_throughput(&format!("scaled_add(p=etaF+e)/d={d}"), (4 * d) as u64, || {
        ops::scaled_add(0.01, &x, &e, &mut out);
        out[0]
    });
    let mut w = rng.normal_vec(d);
    b.bench_with_throughput(&format!("axpy/d={d}"), (4 * d) as u64, || {
        ops::axpy(-0.01, &x, &mut w);
        w[0]
    });
    b.bench_with_throughput(&format!("all_finite/d={d}"), (4 * d) as u64, || {
        ops::all_finite(&x)
    });
    b.finish();
}
