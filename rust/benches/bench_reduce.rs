//! PERF/A-B: windowed incremental reduce vs close-time barrier reduce
//! under **skewed arrivals** — the scenario the windowed fold exists
//! for. Worker payloads land strictly one at a time (gate-held
//! [`DelayPlan`] arrivals, released in worker-id order from the leader's
//! own arrival callback — no sleeps, no timing races): worker w+1's
//! uplink gate opens only after worker w's payload has been accepted,
//! so the windowed engine provably folds each prefix extension while
//! the next worker is still gate-held.
//!
//! The metric is **post-last-arrival close time**: the leader clock from
//! the moment the final payload lands (before its accept) to the
//! averaged output being ready.
//!
//! - `barrier`: that window contains the last decode + the whole
//!   M-worker fold + the 1/M scale.
//! - `windowed`: the first M−1 folds already ran inside the gather, so
//!   the window contains the last decode + a one-worker fold + the
//!   scale.
//!
//! Both produce bitwise-identical averages (`tests/integration_aggregate.rs`);
//! the harness asserts the windowed arm's mean close time is strictly
//! lower, and prints the A/B.

use dqgan::benchutil::Bench;
use dqgan::comm::{inproc_cluster_with_plan, DelayPlan, Message, ServerEnd, WorkerEnd};
use dqgan::compress::{compressor_from_spec, Compressor};
use dqgan::config::{AggMode, AggregatorConfig, KernelMode, ReduceMode};
use dqgan::kernels;
use dqgan::ps::{Aggregator, Decoder};
use dqgan::util::rng::Pcg32;
use std::sync::Arc;
use std::time::{Duration, Instant};

const M: usize = 8;
const D: usize = 400_708; // DCGAN dim

fn main() {
    let mut b = if std::env::var_os("DQGAN_BENCH_MS").is_some() {
        Bench::new("reduce")
    } else {
        Bench::new("reduce").with_budget(Duration::from_millis(400), Duration::from_millis(60))
    };

    let codec = compressor_from_spec("linf8").unwrap();
    let mut rng = Pcg32::new(37);
    let wires: Vec<Vec<u8>> = (0..M)
        .map(|_| {
            let v = rng.normal_vec(D);
            let mut wire = Vec::new();
            codec.compress_encoded(&v, &mut rng, &mut wire);
            wire
        })
        .collect();
    let decoder: Decoder = {
        let c = compressor_from_spec("linf8").unwrap();
        Arc::new(move |bytes: &[u8], out: &mut [f32]| c.decode_into(bytes, out))
    };

    // (Σ post-last-arrival close secs, iterations) per arm.
    b.set_threads(M);
    let mut close_sums: [(f64, u64); 2] = [(0.0, 0); 2];
    for (arm, reduce) in [(0usize, ReduceMode::Barrier), (1usize, ReduceMode::Windowed)] {
        let tag = if arm == 0 { "barrier" } else { "windowed" };
        let mut agg = Aggregator::new(
            AggregatorConfig { mode: AggMode::Streaming, reduce, ..Default::default() },
            D,
            M,
        );
        let decoder = decoder.clone();
        let wires = wires.clone();
        let acc = &mut close_sums[arm];
        b.bench(&format!("skewed-arrival/close/{tag}/M={M}/d={D}"), || {
            let plan = DelayPlan::new();
            // Workers 1..M start gate-held; worker 0 sends immediately.
            for w in 1..M as u32 {
                plan.hold(w, 0);
            }
            let (mut server, worker_ends, _) = inproc_cluster_with_plan(M, plan.clone());
            let handles: Vec<_> = worker_ends
                .into_iter()
                .enumerate()
                .map(|(i, mut w)| {
                    let wire = wires[i].clone();
                    std::thread::spawn(move || {
                        // Blocks on the uplink gate until the leader has
                        // accepted worker i−1's payload.
                        w.send(Message::payload(i as u32, 0, wire)).unwrap();
                    })
                })
                .collect();
            let mut accepted = 0usize;
            let mut last_arrival: Option<Instant> = None;
            agg.begin_round(0);
            server
                .recv_round_streaming(&mut |msg| {
                    accepted += 1;
                    if accepted == M {
                        // The final payload just landed: everything from
                        // here to the averaged output is close-time work.
                        last_arrival = Some(Instant::now());
                    } else {
                        // Structural skew proof: the next worker is still
                        // provably gate-held while this one decodes+folds.
                        assert!(plan.is_held(accepted as u32, 0));
                    }
                    let res = agg.accept(&msg, &decoder);
                    // Release the next arrival only after this accept
                    // (decode + windowed fold) has fully completed.
                    if accepted < M {
                        plan.release(accepted as u32, 0);
                    }
                    res
                })
                .unwrap();
            let avg0 = agg.finish_round().unwrap()[0];
            let close_secs = last_arrival.expect("all M arrived").elapsed().as_secs_f64();
            acc.0 += close_secs;
            acc.1 += 1;
            for h in handles {
                h.join().unwrap();
            }
            avg0
        });
    }

    let mean = |(s, n): (f64, u64)| if n == 0 { 0.0 } else { s / n as f64 };
    let (barrier, windowed) = (mean(close_sums[0]), mean(close_sums[1]));
    // Guard the A/B assertion against DQGAN_BENCH_FILTER runs that
    // executed only one arm.
    if close_sums.iter().all(|&(_, n)| n > 0) {
        println!(
            "post-last-arrival close time (mean): barrier {:.3} ms, windowed {:.3} ms ({:.2}x)",
            barrier * 1e3,
            windowed * 1e3,
            if windowed > 0.0 { barrier / windowed } else { f64::INFINITY }
        );
        assert!(
            windowed < barrier,
            "windowed reduce must shorten the post-last-arrival close: \
             windowed {windowed} >= barrier {barrier}"
        );
    }

    // Scalar-vs-SIMD fold kernel A/B: the shard accumulate + 1/M scale
    // that dominates reduce time, isolated from arrival plumbing (both
    // arms are bitwise-identical — tests/prop_kernels.rs). This is the
    // `reduce/fold/...` speedup_gates pair in the committed trajectory.
    {
        b.set_threads(1);
        let mut rng = Pcg32::new(11);
        let slots: Vec<Vec<f32>> = (0..M).map(|_| rng.normal_vec(D)).collect();
        let mut acc = vec![0.0f32; D];
        let mut out = vec![0.0f32; D];
        let inv = 1.0 / M as f32;
        for (mode, tag) in [(KernelMode::Scalar, "scalar"), (KernelMode::Simd, "simd")] {
            let _g = kernels::scoped_mode(mode);
            b.bench_with_throughput(&format!("fold/M={M}/d={D}/{tag}"), (M * D * 4) as u64, || {
                for x in acc.iter_mut() {
                    *x = 0.0;
                }
                for s in &slots {
                    kernels::add_assign(&mut acc, s);
                }
                kernels::scale_into(&mut out, &acc, inv);
                out[0]
            });
        }
    }
    b.finish();
}
