//! Figure 4 regeneration (bench-target form): speedup vs workers for
//! DQGAN-8bit vs CPOAdam-fp32, measured compute + byte-exact comm model.
//! Canonical entry point: `dqgan figures --id fig4`.

fn main() {
    if !dqgan::runtime::artifacts_dir().join("manifest.json").exists() {
        eprintln!("SKIP fig4: artifacts not built (run `make artifacts`)");
        return;
    }
    let fast = std::env::var("DQGAN_FAST").map(|v| v != "0").unwrap_or(true);
    dqgan::exp::fig4::run(fast).expect("fig4 run failed");
}
