//! PERF/A-B: the readiness-loop transport (`--transport evloop`) vs the
//! per-worker-thread baseline (`--transport threads`) at a leader
//! fan-out of M=64 in-process workers under **skewed arrivals** — every
//! feeder scrambles its per-round send order with a seeded shuffle, so
//! uplink frames reach the leader in an order no worker-id loop
//! predicts (the arrival pattern the readiness loop is built for).
//!
//! Both arms run the same seeded workload through the real
//! [`serve_rounds_with`] pipelined engine; the A/B measures the
//! per-run cost of the leader's downlink machinery — the threaded arm
//! spawns, feeds and joins an M-thread writer army every run, the
//! evloop arm one delivery loop — and **structurally asserts** the
//! thread-count claim on `/proc/self/task`: the threaded leader's peak
//! live-thread count grows with M while the evloop leader's stays flat
//! (bounded by the feeder pool plus one loop thread, independent of M).
//! Workers are driven by a fixed-size feeder pool in both arms, so the
//! only thread-count difference under test is the leader's.

use dqgan::benchutil::Bench;
use dqgan::comm::inproc::InprocWorkerEnd;
use dqgan::comm::{inproc_cluster, inproc_cluster_evloop, Message, MsgKind, ServerEnd, WorkerEnd};
use dqgan::compress::{Compressor, Identity};
use dqgan::config::AggregatorConfig;
use dqgan::ps::{serve_rounds_with, Decoder};
use dqgan::util::rng::Pcg32;
use dqgan::util::threads::live_threads;
use std::sync::Arc;
use std::time::Duration;

const M: usize = 64;
const D: usize = 20_003;
const ROUNDS: u64 = 3;
const FEEDERS: usize = 8;
/// Evloop-arm flatness bound: feeder pool + one delivery loop + slack
/// for harness jitter. The threaded arm's floor is `base + M` writers.
const FLAT_SLACK: usize = 4;

fn identity_decoder() -> Decoder {
    Arc::new(|bytes: &[u8], out: &mut [f32]| Identity.decode_into(bytes, out))
}

/// Drive one feeder's chunk of workers through all rounds, sending in a
/// per-round shuffled order (the skew) and acking each broadcast as
/// applied (a no-op on the threaded transport).
fn drive_chunk(ends: &mut [InprocWorkerEnd], wires: &[Vec<u8>], seed: u64) {
    let mut rng = Pcg32::new(seed);
    for round in 0..ROUNDS {
        let mut order: Vec<usize> = (0..ends.len()).collect();
        rng.shuffle(&mut order);
        for i in order {
            let id = ends[i].id();
            ends[i].send(Message::payload(id, round, wires[i].clone())).unwrap();
        }
        for end in ends.iter_mut() {
            let b = end.recv().unwrap();
            assert_eq!(b.round, round);
            end.ack(round).unwrap();
        }
    }
    for end in ends.iter_mut() {
        assert_eq!(end.recv().unwrap().kind, MsgKind::Shutdown);
    }
}

/// One full pipelined run over either transport; returns the peak live
/// OS-thread count sampled at every round record.
fn run_once(evloop: bool, wires: &[Vec<u8>]) -> usize {
    let (mut server, ends, _counter): (Box<dyn ServerEnd>, _, _) = if evloop {
        let (s, e, c) = inproc_cluster_evloop(M);
        (Box::new(s), e, c)
    } else {
        let (s, e, c) = inproc_cluster(M);
        (Box::new(s), e, c)
    };
    let chunk = M.div_ceil(FEEDERS);
    let mut chunks: Vec<(Vec<InprocWorkerEnd>, Vec<Vec<u8>>)> = Vec::new();
    let mut it = ends.into_iter().zip(wires.iter().cloned());
    loop {
        let c: Vec<_> = it.by_ref().take(chunk).collect();
        if c.is_empty() {
            break;
        }
        chunks.push(c.into_iter().unzip());
    }
    let mut peak = 0usize;
    std::thread::scope(|s| {
        let handles: Vec<_> = chunks
            .into_iter()
            .enumerate()
            .map(|(k, (mut ends, wires))| {
                s.spawn(move || drive_chunk(&mut ends, &wires, 0xFEED + k as u64))
            })
            .collect();
        serve_rounds_with(
            &mut *server,
            identity_decoder(),
            D,
            ROUNDS,
            AggregatorConfig::pipelined_with_depth(2),
            |_| peak = peak.max(live_threads()),
        )
        .unwrap();
        for h in handles {
            h.join().unwrap();
        }
    });
    drop(server);
    peak
}

fn main() {
    let mut b = if std::env::var_os("DQGAN_BENCH_MS").is_some() {
        Bench::new("evloop")
    } else {
        Bench::new("evloop").with_budget(Duration::from_millis(400), Duration::from_millis(60))
    };
    let mut rng = Pcg32::new(31);
    let wires: Vec<Vec<u8>> = (0..M)
        .map(|_| {
            let v = rng.normal_vec(D);
            let mut wire = Vec::new();
            Identity.encode(&v, &mut wire);
            wire
        })
        .collect();

    let mut peaks = [0usize; 2]; // [threads, evloop]
    for (arm, evloop) in [(0usize, false), (1usize, true)] {
        let tag = if evloop { "evloop" } else { "threads" };
        // Leader-side thread metadata: M writers vs one readiness loop.
        b.set_threads(if evloop { 1 } else { M });
        let wires = &wires;
        let peak = &mut peaks[arm];
        b.bench(&format!("fanout/run/{tag}/M={M}/d={D}"), || {
            let p = run_once(evloop, wires);
            *peak = (*peak).max(p);
            p
        });
    }
    let (threads_peak, evloop_peak) = (peaks[0], peaks[1]);
    // live_threads() reads /proc/self/task — 0 on non-Linux, where the
    // structural claim cannot be sampled and only the timing A/B runs.
    if threads_peak > 0 {
        println!(
            "peak live threads per run: threaded {threads_peak}, evloop {evloop_peak} \
             (feeders {FEEDERS}, M {M})"
        );
        assert!(
            threads_peak >= M,
            "threaded transport must show its M-wide writer army: peak {threads_peak} < {M}"
        );
        assert!(
            evloop_peak <= threads_peak - M + FEEDERS + FLAT_SLACK,
            "evloop leader thread count must be flat in M: peak {evloop_peak} \
             vs threaded {threads_peak}"
        );
    }
    b.finish();
}
