//! PERF: end-to-end round latency breakdown on the real stack — gradient
//! (XLA), quantize+encode, server decode+aggregate — per model. The
//! numbers behind EXPERIMENTS.md §Perf's "L3 must not be the bottleneck".

use dqgan::benchutil::Bench;
use dqgan::compress::{compressor_from_spec, Compressor};
use dqgan::data::{GaussianMixture2D, SynthImages};
use dqgan::grad::GradientSource;
use dqgan::runtime::{artifacts_dir, Runtime, XlaGradSource};
use dqgan::tensor::ops;
use dqgan::util::rng::Pcg32;
use std::time::Duration;

fn main() {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let rt = Runtime::from_default_dir().unwrap();
    let mut b = Bench::new("step_latency")
        .with_budget(Duration::from_millis(1500), Duration::from_millis(400));

    // MLP model (355 params): the round should be L3-dominated here.
    {
        let mut src = XlaGradSource::mlp(&rt, GaussianMixture2D::ring(8, 2.0, 0.1)).unwrap();
        let mut rng = Pcg32::new(1);
        let w = src.init_params(&mut rng);
        let mut g = vec![0.0; src.dim()];
        let batch = src.artifact_batch();
        src.grad(&w, batch, &mut rng, &mut g).unwrap();
        b.bench("mlp/grad-xla", || src.grad(&w, batch, &mut rng, &mut g).unwrap());
    }

    // DCGAN model (400,708 params).
    {
        let mut src = XlaGradSource::dcgan(&rt, SynthImages::cifar_like(1)).unwrap();
        let mut rng = Pcg32::new(2);
        let w = src.init_params(&mut rng);
        let d = src.dim();
        let mut g = vec![0.0; d];
        let batch = src.artifact_batch();
        src.grad(&w, batch, &mut rng, &mut g).unwrap();
        b.bench("dcgan/grad-xla", || src.grad(&w, batch, &mut rng, &mut g).unwrap());

        let c = compressor_from_spec("linf8").unwrap();
        let mut buf = Vec::with_capacity(c.encoded_size(d));
        b.bench_with_throughput("dcgan/quantize+encode", (4 * d) as u64, || {
            buf.clear();
            c.compress_encoded(&g, &mut rng, &mut buf)
        });
        let wire = buf.clone();
        b.bench_with_throughput("dcgan/server-decode", (4 * d) as u64, || {
            c.decode(&wire, d).unwrap()
        });
        let decoded: Vec<Vec<f32>> = (0..4).map(|_| c.decode(&wire, d).unwrap()).collect();
        let refs: Vec<&[f32]> = decoded.iter().map(|v| v.as_slice()).collect();
        let mut avg = vec![0.0f32; d];
        b.bench_with_throughput("dcgan/server-average-M4", (4 * d * 4) as u64, || {
            ops::mean_into(&refs, &mut avg);
            avg[0]
        });
    }
    b.finish();
}
