//! PERF: bit-packing codec and wire-framing throughput (isolated from the
//! quantization math).

use dqgan::benchutil::Bench;
use dqgan::comm::Message;
use dqgan::compress::{BitReader, BitWriter};
use dqgan::util::rng::Pcg32;

fn main() {
    let mut b = Bench::new("codec");
    let mut rng = Pcg32::new(3);
    // Raw bit packing at the paper's 8-bit setting (1 sign + 7 level bits).
    for &n in &[100_000usize, 1_000_000] {
        let levels: Vec<u32> = (0..n).map(|_| rng.below(128)).collect();
        let signs: Vec<u32> = (0..n).map(|_| rng.below(2)).collect();
        b.bench_with_throughput(&format!("bitpack-write/8bit/n={n}"), (4 * n) as u64, || {
            let mut w = BitWriter::with_capacity_bits(n * 8);
            for i in 0..n {
                w.write(signs[i], 1);
                w.write(levels[i], 7);
            }
            w.into_bytes()
        });
        let bytes = {
            let mut w = BitWriter::with_capacity_bits(n * 8);
            for i in 0..n {
                w.write(signs[i], 1);
                w.write(levels[i], 7);
            }
            w.into_bytes()
        };
        b.bench_with_throughput(&format!("bitpack-read/8bit/n={n}"), (4 * n) as u64, || {
            let mut r = BitReader::new(&bytes);
            let mut acc = 0u32;
            for _ in 0..n {
                acc ^= r.read(1).unwrap();
                acc ^= r.read(7).unwrap();
            }
            acc
        });
    }
    // Message framing (encode + CRC + decode).
    for &n in &[100_000usize, 1_600_000] {
        let payload = vec![0xA5u8; n];
        let msg = Message::payload(3, 17, payload);
        b.bench_with_throughput(&format!("frame-encode/n={n}"), n as u64, || msg.encode());
        let frame = msg.encode();
        b.bench_with_throughput(&format!("frame-decode/n={n}"), n as u64, || {
            Message::decode(&frame).unwrap()
        });
    }
    b.finish();
}
