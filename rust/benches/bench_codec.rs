//! PERF: bit-packing codec and wire-framing throughput (isolated from the
//! quantization math).

use dqgan::benchutil::Bench;
use dqgan::comm::Message;
use dqgan::compress::{compressor_from_spec, BitReader, BitWriter, Compressor};
use dqgan::config::KernelMode;
use dqgan::kernels;
use dqgan::util::bytes::{fnv1a64_f32, put_f32_slice};
use dqgan::util::rng::Pcg32;

const AB: [(KernelMode, &str); 2] =
    [(KernelMode::Scalar, "scalar"), (KernelMode::Simd, "simd")];

fn main() {
    let mut b = Bench::new("codec");
    let mut rng = Pcg32::new(3);
    // Raw bit packing at the paper's 8-bit setting (1 sign + 7 level bits).
    for &n in &[100_000usize, 1_000_000] {
        let levels: Vec<u32> = (0..n).map(|_| rng.below(128)).collect();
        let signs: Vec<u32> = (0..n).map(|_| rng.below(2)).collect();
        b.bench_with_throughput(&format!("bitpack-write/8bit/n={n}"), (4 * n) as u64, || {
            let mut w = BitWriter::with_capacity_bits(n * 8);
            for i in 0..n {
                w.write(signs[i], 1);
                w.write(levels[i], 7);
            }
            w.into_bytes()
        });
        let bytes = {
            let mut w = BitWriter::with_capacity_bits(n * 8);
            for i in 0..n {
                w.write(signs[i], 1);
                w.write(levels[i], 7);
            }
            w.into_bytes()
        };
        b.bench_with_throughput(&format!("bitpack-read/8bit/n={n}"), (4 * n) as u64, || {
            let mut r = BitReader::new(&bytes);
            let mut acc = 0u32;
            for _ in 0..n {
                acc ^= r.read(1).unwrap();
                acc ^= r.read(7).unwrap();
            }
            acc
        });
    }
    // Message framing (encode + CRC + decode).
    for &n in &[100_000usize, 1_600_000] {
        let payload = vec![0xA5u8; n];
        let msg = Message::payload(3, 17, payload);
        b.bench_with_throughput(&format!("frame-encode/n={n}"), n as u64, || msg.encode());
        let frame = msg.encode();
        b.bench_with_throughput(&format!("frame-decode/n={n}"), n as u64, || {
            Message::decode(&frame).unwrap()
        });
    }

    // ------------------------------------------------------------------
    // Scalar-vs-SIMD kernel A/Bs. Both arms are bitwise-identical
    // (tests/prop_kernels.rs); these pairs pin the speedup in the
    // committed trajectory — BENCH_*.json `speedup_gates` entries point
    // at `<case>/scalar` ÷ `<case>/simd`.
    // ------------------------------------------------------------------
    let n = 1_000_000usize;
    let v = rng.normal_vec(n);

    for spec in ["qsgd8", "linf8", "terngrad", "sign"] {
        let c = compressor_from_spec(spec).unwrap();
        for (mode, tag) in AB {
            let _g = kernels::scoped_mode(mode);
            let mut buf = Vec::new();
            b.bench_with_throughput(&format!("{spec}-encode/1M/{tag}"), (4 * n) as u64, || {
                buf.clear();
                c.compress_encoded(&v, &mut rng, &mut buf);
                buf.len()
            });
        }
        let wire = {
            let mut buf = Vec::new();
            c.compress_encoded(&v, &mut rng, &mut buf);
            buf
        };
        let mut out = vec![0.0f32; n];
        for (mode, tag) in AB {
            let _g = kernels::scoped_mode(mode);
            b.bench_with_throughput(&format!("{spec}-decode/1M/{tag}"), (4 * n) as u64, || {
                c.decode_into(&wire, &mut out).unwrap();
                out[0]
            });
        }
    }

    // Broadcast-frame building blocks: f32→LE serialization and the
    // round-checksum hash.
    for (mode, tag) in AB {
        let _g = kernels::scoped_mode(mode);
        let mut buf: Vec<u8> = Vec::with_capacity(4 * n);
        b.bench_with_throughput(&format!("put-f32-slice/1M/{tag}"), (4 * n) as u64, || {
            buf.clear();
            put_f32_slice(&mut buf, &v);
            buf.len()
        });
        b.bench_with_throughput(&format!("fnv1a64-f32/1M/{tag}"), (4 * n) as u64, || {
            fnv1a64_f32(&v)
        });
    }

    // Whole-frame encode (CRC-dominated: byte-at-a-time vs slicing-by-8).
    {
        let payload = vec![0xA5u8; 1_600_000];
        let msg = Message::payload(3, 17, payload);
        for (mode, tag) in AB {
            let _g = kernels::scoped_mode(mode);
            b.bench_with_throughput(&format!("frame-encode-ab/n=1600000/{tag}"), 1_600_000, || {
                msg.encode()
            });
        }
    }
    b.finish();
}
