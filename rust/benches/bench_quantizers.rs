//! PERF: compressor throughput (quantize + encode, the per-round worker
//! cost that competes with gradient compute). Includes the XLA/Pallas
//! quantizer when artifacts are present, so native-vs-kernel cost is
//! directly comparable.

use dqgan::benchutil::Bench;
use dqgan::compress::{compressor_from_spec, Compressor};
use dqgan::util::rng::Pcg32;

fn main() {
    let mut b = Bench::new("quantizers");
    let mut rng = Pcg32::new(42);
    for &d in &[10_000usize, 400_708, 1_000_000] {
        let v = rng.normal_vec(d);
        let bytes = (4 * d) as u64;
        for spec in
            ["linf8", "linf(bits=8,block=1024)", "qsgd8", "topk(f=0.1)", "sign", "terngrad", "identity"]
        {
            let c = compressor_from_spec(spec).unwrap();
            let mut r = Pcg32::new(7);
            let mut buf = Vec::with_capacity(c.encoded_size(d));
            b.bench_with_throughput(&format!("{spec}/d={d}"), bytes, || {
                buf.clear();
                c.compress_encoded(&v, &mut r, &mut buf)
            });
        }
    }
    // Decode path (server side).
    {
        let d = 400_708usize;
        let v = rng.normal_vec(d);
        for spec in ["linf8", "qsgd8", "sign"] {
            let c = compressor_from_spec(spec).unwrap();
            let mut r = Pcg32::new(9);
            let mut buf = Vec::new();
            c.compress_encoded(&v, &mut r, &mut buf);
            b.bench_with_throughput(&format!("decode/{spec}/d={d}"), (4 * d) as u64, || {
                c.decode(&buf, d).unwrap()
            });
        }
    }
    // XLA/Pallas fused kernel, if artifacts are available.
    if dqgan::runtime::artifacts_dir().join("manifest.json").exists() {
        let rt = dqgan::runtime::Runtime::from_default_dir().unwrap();
        let q = dqgan::runtime::XlaQuantizer::new(&rt, "quantize_ef_dcgan").unwrap();
        let d = q.dim();
        let v = rng.normal_vec(d);
        let mut r = Pcg32::new(11);
        let _ = q.quantize_ef(&v, &mut r).unwrap(); // warm the compile
        b.bench_with_throughput(&format!("xla-pallas-quantize_ef/d={d}"), (4 * d) as u64, || {
            q.quantize_ef(&v, &mut r).unwrap()
        });
    } else {
        eprintln!("(skipping XLA quantizer case: run `make artifacts`)");
    }
    b.finish();
}
