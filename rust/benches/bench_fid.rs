//! PERF: metric-path costs — feature extraction (native conv net), the
//! Newton–Schulz matrix sqrt, and the full FID computation.

use dqgan::benchutil::Bench;
use dqgan::data::SynthImages;
use dqgan::linalg::{covariance, sqrtm_newton_schulz};
use dqgan::metrics::{fid_from_features, FeatureNet, FEATURE_DIM};
use dqgan::util::rng::Pcg32;

fn main() {
    let mut b = Bench::new("fid");
    let ds = SynthImages::cifar_like(1);
    let net = FeatureNet::new();
    let mut rng = Pcg32::new(4);
    let (imgs, _) = ds.sample_batch(64, &mut rng);
    b.bench("feature_net/64imgs", || net.features_batch(&imgs));

    let n = 512usize;
    let feats_a: Vec<f32> = (0..n * FEATURE_DIM).map(|_| rng.normal()).collect();
    let feats_b: Vec<f32> = (0..n * FEATURE_DIM).map(|_| 0.5 + rng.normal()).collect();
    b.bench("covariance/512x32", || covariance(&feats_a, n, FEATURE_DIM));
    let cov = covariance(&feats_a, n, FEATURE_DIM);
    b.bench("sqrtm-newton-schulz/32x32", || {
        sqrtm_newton_schulz(&cov, FEATURE_DIM, 1e-6, 64)
    });
    b.bench("fid-total/512-vs-512", || {
        fid_from_features(&feats_a, n, &feats_b, n, FEATURE_DIM)
    });
    b.finish();
}
