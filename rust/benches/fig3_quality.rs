//! Figure 3 regeneration (bench-target form): IS/FID vs epoch on the
//! CelebA-like dataset. See fig2_quality.rs; canonical entry point is
//! `dqgan figures --id fig3`.

fn main() {
    let fast = std::env::var("DQGAN_FAST").map(|v| v != "0").unwrap_or(true);
    if !dqgan::runtime::artifacts_dir().join("manifest.json").exists() {
        eprintln!("SKIP fig3: artifacts not built (run `make artifacts`)");
        return;
    }
    dqgan::exp::images::run(dqgan::exp::images::ImageFigure::Fig3Faces, fast)
        .expect("fig3 run failed");
}
