//! PERF: the streaming (decode-on-arrival) round engine under **skewed
//! arrivals** — the scenario the leader actually faces: worker payloads
//! do not land simultaneously, and a gather-then-aggregate barrier
//! serializes all decode work behind the slowest worker.
//!
//! Each case runs one full leader round against an in-process cluster
//! whose worker `i` delays its send by `i · stagger`, then measures
//! leader wall-clock from round start to averaged output:
//!
//! - `sequential` / `sharded`: `recv_round` barrier, then decode+reduce —
//!   round time ≈ last arrival + all decode work.
//! - `streaming`: `recv_round_streaming` + `Aggregator::accept` — early
//!   payloads decode while later ones are still "in flight", so round
//!   time ≈ last arrival + one decode + reduce.
//!
//! All three produce bitwise-identical averages (see
//! `tests/integration_aggregate.rs`); this harness times the leader's
//! round wall-clock directly. (In real training runs the same overlap
//! shows up as the `wait_secs`/`agg_secs` split `ps::serve_rounds_with`
//! records per round.)

use dqgan::benchutil::Bench;
use dqgan::comm::{inproc_cluster, Message, ServerEnd, WorkerEnd};
use dqgan::compress::{compressor_from_spec, Compressor};
use dqgan::config::{AggMode, AggregatorConfig};
use dqgan::ps::{Aggregator, Decoder};
use dqgan::util::rng::Pcg32;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    // Sleep-heavy cases: keep the per-case budget tight by default, but
    // let the standard DQGAN_BENCH_MS / DQGAN_BENCH_WARMUP_MS knobs win
    // when set (Bench::new reads them).
    let mut b = if std::env::var_os("DQGAN_BENCH_MS").is_some() {
        Bench::new("streaming")
    } else {
        Bench::new("streaming").with_budget(Duration::from_millis(400), Duration::from_millis(60))
    };
    let d = 400_708usize; // DCGAN dim
    let m = 8usize;
    let stagger = Duration::from_millis(1);

    let codec = compressor_from_spec("linf8").unwrap();
    let mut rng = Pcg32::new(11);
    let wires: Vec<Vec<u8>> = (0..m)
        .map(|_| {
            let v = rng.normal_vec(d);
            let mut wire = Vec::new();
            codec.compress_encoded(&v, &mut rng, &mut wire);
            wire
        })
        .collect();
    let decoder: Decoder = {
        let c = compressor_from_spec("linf8").unwrap();
        Arc::new(move |bytes: &[u8], out: &mut [f32]| c.decode_into(bytes, out))
    };

    for mode in [AggMode::Sequential, AggMode::Sharded, AggMode::Streaming] {
        let tag = match mode {
            AggMode::Sequential => "sequential",
            AggMode::Sharded => "sharded",
            AggMode::Streaming => "streaming",
            // Pipelining changes the downlink, not this uplink-side A/B
            // (benches/bench_pipeline.rs covers it).
            AggMode::Pipelined => "pipelined",
        };
        let mut agg = Aggregator::new(AggregatorConfig { mode, ..Default::default() }, d, m);
        b.bench(&format!("skewed-arrival/round/{tag}/M={m}/d={d}"), || {
            let (mut server, workers, _) = inproc_cluster(m);
            let handles: Vec<_> = workers
                .into_iter()
                .enumerate()
                .map(|(i, mut w)| {
                    let wire = wires[i].clone();
                    std::thread::spawn(move || {
                        // Worker i's payload lands i·stagger late.
                        std::thread::sleep(stagger * i as u32);
                        w.send(Message::payload(i as u32, 0, wire)).unwrap();
                    })
                })
                .collect();
            let out0 = if mode == AggMode::Streaming {
                agg.begin_round(0);
                server
                    .recv_round_streaming(&mut |msg| agg.accept(&msg, &decoder))
                    .unwrap();
                agg.finish_round().unwrap()[0]
            } else {
                let msgs = server.recv_round().unwrap();
                agg.aggregate(0, &msgs, &decoder).unwrap()[0]
            };
            for h in handles {
                h.join().unwrap();
            }
            out0
        });
    }
    b.finish();
}
