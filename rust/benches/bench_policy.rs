//! PERF/A-B: round-completion policies under a **scripted straggler** —
//! the scenario the policy engine exists for. Worker `M−1`'s payload is
//! held behind a [`DelayPlan`] gate every round, so under the `full`
//! barrier the leader cannot make progress until the gate opens, while
//! `kofm:M−1` closes each round on the M−1 prompt workers and
//! `deadline:MS` closes a grace window after the quorum.
//!
//! The straggler is **gate-based, not sleep-based**: the A/B asserts
//! structural facts the acceptance criteria name —
//! `workers_included`/`workers_skipped` per round, the gate provably
//! still held when a partial round's record is produced, and
//! `wait_secs` covering the grace window under `deadline` — and then
//! reports the leader's measured round wall-clock for each policy.

use dqgan::benchutil::Bench;
use dqgan::comm::{inproc_cluster_with_plan, DelayPlan, Message, MsgKind, WorkerEnd};
use dqgan::compress::{compressor_from_spec, Compressor};
use dqgan::config::{AggMode, AggregatorConfig, PolicyConfig};
use dqgan::ps::{serve_rounds_with, Decoder};
use dqgan::util::rng::Pcg32;
use std::sync::Arc;
use std::time::Duration;

const M: usize = 4;
const D: usize = 100_003;
const ROUNDS: u64 = 2;
const GRACE_MS: u64 = 5;

fn main() {
    let mut b = if std::env::var_os("DQGAN_BENCH_MS").is_some() {
        Bench::new("policy")
    } else {
        Bench::new("policy").with_budget(Duration::from_millis(400), Duration::from_millis(60))
    };

    let codec = compressor_from_spec("linf8").unwrap();
    let mut rng = Pcg32::new(13);
    let wires: Vec<Vec<u8>> = (0..M)
        .map(|_| {
            let v = rng.normal_vec(D);
            let mut wire = Vec::new();
            codec.compress_encoded(&v, &mut rng, &mut wire);
            wire
        })
        .collect();
    let decoder: Decoder = {
        let c = compressor_from_spec("linf8").unwrap();
        Arc::new(move |bytes: &[u8], out: &mut [f32]| c.decode_into(bytes, out))
    };

    let cases: [(&str, PolicyConfig, bool); 3] = [
        // Baseline: full barrier, no straggler (everyone sends promptly).
        ("full/no-straggler", PolicyConfig::Full, false),
        // kofm closes on the prompt workers; the gate is never released
        // mid-round, proving the round cannot have waited on it.
        ("kofm/straggler-heldout", PolicyConfig::KofM { k: M - 1 }, true),
        // deadline waits its grace window, then closes without the
        // straggler.
        (
            "deadline/straggler-heldout",
            PolicyConfig::Deadline { grace_ms: GRACE_MS, arm_at: M - 1 },
            true,
        ),
    ];

    for (tag, policy, hold) in cases {
        let decoder = decoder.clone();
        let wires = wires.clone();
        b.bench(&format!("scripted-straggler/run/{tag}/M={M}/d={D}"), || {
            let straggler = (M - 1) as u32;
            let plan = DelayPlan::new();
            if hold {
                for r in 0..ROUNDS {
                    plan.hold(straggler, r);
                }
            }
            let (mut server, worker_ends, _) = inproc_cluster_with_plan(M, plan.clone());
            let handles: Vec<_> = worker_ends
                .into_iter()
                .enumerate()
                .map(|(i, mut w)| {
                    let wire = wires[i].clone();
                    std::thread::spawn(move || {
                        for round in 0..ROUNDS {
                            // A gated send blocks here until released.
                            if w.send(Message::payload(i as u32, round, wire.clone())).is_err()
                            {
                                return; // leader gone (held-out teardown)
                            }
                            match w.recv() {
                                Ok(msg) if msg.kind == MsgKind::Shutdown => return,
                                Ok(_) => {}
                                Err(_) => return,
                            }
                        }
                        let _ = w.recv(); // trailing shutdown
                    })
                })
                .collect();
            let cfg = AggregatorConfig { mode: AggMode::Streaming, policy, ..Default::default() };
            let plan_probe = plan.clone();
            let recs =
                serve_rounds_with(&mut server, decoder.clone(), D, ROUNDS, cfg, |rec| {
                    if hold {
                        // Structural proof (acceptance criterion): the
                        // round closed while the straggler's gate was
                        // still held — it cannot have been waited on.
                        assert!(plan_probe.is_held(straggler, rec.round));
                        assert_eq!(rec.workers_included, M - 1);
                        assert_eq!(rec.workers_skipped, 1);
                        if let PolicyConfig::Deadline { grace_ms, .. } = policy {
                            let grace = grace_ms as f64 / 1e3;
                            assert!(
                                rec.wait_secs >= grace * 0.5,
                                "deadline round must block through the grace window: \
                                 wait {} < {}",
                                rec.wait_secs,
                                grace
                            );
                        }
                    } else {
                        assert_eq!(rec.workers_included, M);
                    }
                })
                .unwrap();
            // Open every gate, then tear the cluster down so the blocked
            // straggler unblocks and exits.
            plan.release_all();
            drop(server);
            for h in handles {
                h.join().unwrap();
            }
            recs.len()
        });
    }
    b.finish();
}
