//! PERF/A-B: the pipelined round engine (`--agg pipelined`) under a
//! **scripted slow receiver** — the scenario async broadcast exists for.
//! Worker `M−1` never delivers an on-time payload (uplink gates held all
//! run) *and* is slow to receive its broadcasts (downlink gates held per
//! round), so under `--agg streaming` the leader's synchronous broadcast
//! loop blocks on that worker's downlink every round, while `--agg
//! pipelined` queues the frame onto the worker's writer thread and
//! immediately gathers round t+1 from the prompt workers.
//!
//! The skew is **gate-based, not sleep-based** (the PR-3 [`DelayPlan`]
//! pattern): in the pipelined arm every round r ≥ 1 asserts, on the
//! round record itself, that round r−1's downlink gate is *provably
//! still held* — the gather ran while the previous broadcast was in
//! flight (and `overlap_secs` reports the overlap directly). In the
//! streaming arm a monitor thread plays the slow NIC: it releases round
//! r's downlink gate only once every prompt worker has pushed its round
//! r+1 payload, so the leader demonstrably sat in `broadcast` for the
//! window the pipelined arm spends gathering. The A/B then compares the
//! leaders' summed `wait_secs` (which includes downlink blocking):
//! pipelined must come out lower.

use dqgan::benchutil::Bench;
use dqgan::comm::{inproc_cluster_with_plan, DelayPlan, Message, MsgKind, WorkerEnd};
use dqgan::compress::{compressor_from_spec, Compressor};
use dqgan::config::{AggMode, AggregatorConfig, PolicyConfig};
use dqgan::ps::{serve_rounds_with, Decoder};
use dqgan::util::rng::Pcg32;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

const M: usize = 4;
const D: usize = 200_003;
const ROUNDS: u64 = 3;
const STRAGGLER: u32 = (M - 1) as u32;

fn main() {
    let mut b = if std::env::var_os("DQGAN_BENCH_MS").is_some() {
        Bench::new("pipeline")
    } else {
        Bench::new("pipeline").with_budget(Duration::from_millis(400), Duration::from_millis(60))
    };

    let codec = compressor_from_spec("linf8").unwrap();
    let mut rng = Pcg32::new(29);
    let wires: Vec<Vec<u8>> = (0..M)
        .map(|_| {
            let v = rng.normal_vec(D);
            let mut wire = Vec::new();
            codec.compress_encoded(&v, &mut rng, &mut wire);
            wire
        })
        .collect();
    let decoder: Decoder = {
        let c = compressor_from_spec("linf8").unwrap();
        Arc::new(move |bytes: &[u8], out: &mut [f32]| c.decode_into(bytes, out))
    };

    let mut wait_sums: [(f64, u64); 2] = [(0.0, 0); 2]; // (Σ wait, iterations)
    for (arm, mode) in [(0usize, AggMode::Streaming), (1usize, AggMode::Pipelined)] {
        let tag = if arm == 0 { "streaming/sync-broadcast" } else { "pipelined/async-broadcast" };
        let decoder = decoder.clone();
        let wires = wires.clone();
        let acc = &mut wait_sums[arm];
        b.bench(&format!("slow-receiver/run/{tag}/M={M}/d={D}"), || {
            let plan = DelayPlan::new();
            for r in 0..ROUNDS {
                // The straggler's payloads are never on time, and its
                // broadcast deliveries are gated per round.
                plan.hold(STRAGGLER, r);
                plan.hold_down(STRAGGLER, r);
            }
            let (mut server, worker_ends, _) = inproc_cluster_with_plan(M, plan.clone());
            // Prompt workers signal after each payload send (the
            // streaming arm's monitor drives gate releases off these).
            let (sig_tx, sig_rx) = channel::<()>();
            let handles: Vec<_> = worker_ends
                .into_iter()
                .enumerate()
                .map(|(i, mut w)| {
                    let wire = wires[i].clone();
                    let sig = (arm == 0 && (i as u32) != STRAGGLER).then(|| sig_tx.clone());
                    std::thread::spawn(move || {
                        for round in 0..ROUNDS {
                            if w.send(Message::payload(i as u32, round, wire.clone())).is_err()
                            {
                                return; // leader gone (straggler teardown)
                            }
                            if let Some(s) = &sig {
                                let _ = s.send(());
                            }
                            match w.recv() {
                                Ok(msg) if msg.kind == MsgKind::Shutdown => return,
                                Ok(_) => {}
                                Err(_) => return,
                            }
                        }
                        let _ = w.recv(); // trailing shutdown
                    })
                })
                .collect();
            drop(sig_tx);
            // Streaming arm: the monitor releases round r's downlink
            // gate only after every prompt worker has pushed its round
            // r+1 payload — the broadcast provably blocked through that
            // whole production window.
            let monitor = (arm == 0).then(|| {
                let plan = plan.clone();
                std::thread::spawn(move || {
                    let prompt = M - 1;
                    let mut count = 0usize;
                    for r in 0..ROUNDS {
                        let need = prompt * ((r as usize + 2).min(ROUNDS as usize));
                        while count < need {
                            if sig_rx.recv().is_err() {
                                break;
                            }
                            count += 1;
                        }
                        plan.release_down(STRAGGLER, r);
                    }
                })
            });
            let cfg = AggregatorConfig {
                mode,
                pipeline_depth: 2,
                policy: PolicyConfig::KofM { k: M - 1 },
                ..Default::default()
            };
            let plan_probe = plan.clone();
            let recs = serve_rounds_with(&mut server, decoder.clone(), D, ROUNDS, cfg, |rec| {
                assert_eq!(rec.workers_included, M - 1);
                assert_eq!(rec.workers_skipped, 1);
                if arm == 1 {
                    if rec.round >= 1 {
                        // Exact gate-held proof of the overlap: this
                        // round's record exists while the previous
                        // round's broadcast delivery is still gated —
                        // the gather ran concurrently with it.
                        assert!(plan_probe.is_held_down(STRAGGLER, rec.round - 1));
                        assert!(
                            rec.overlap_secs > 0.0,
                            "round {} gather must overlap the in-flight broadcast",
                            rec.round
                        );
                    }
                    if rec.round == ROUNDS - 1 {
                        // Open every gate so the trailing Shutdown can
                        // drain through the writer threads.
                        plan_probe.release_all();
                    }
                }
            })
            .unwrap();
            plan.release_all();
            drop(server);
            for h in handles {
                h.join().unwrap();
            }
            if let Some(m) = monitor {
                m.join().unwrap();
            }
            let wait_sum: f64 = recs.iter().map(|r| r.wait_secs).sum();
            acc.0 += wait_sum;
            acc.1 += 1;
            wait_sum
        });
    }
    let mean = |(s, n): (f64, u64)| if n == 0 { 0.0 } else { s / n as f64 };
    let (stream, pipe) = (mean(wait_sums[0]), mean(wait_sums[1]));
    println!(
        "summed wait_secs per run (mean): streaming {:.3} ms, pipelined {:.3} ms ({:.2}x)",
        stream * 1e3,
        pipe * 1e3,
        if pipe > 0.0 { stream / pipe } else { f64::INFINITY }
    );
    assert!(
        pipe < stream,
        "pipelined mode must lower summed wait_secs under a slow receiver: \
         pipelined {pipe} >= streaming {stream}"
    );
    b.finish();
}
