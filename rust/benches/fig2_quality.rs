//! Figure 2 regeneration (bench-target form): IS/FID vs epoch on the
//! CIFAR-10-like dataset for all three methods, through the full stack.
//! Heavy: pass `--fast` via DQGAN_FAST=1 to shrink.
//!
//! The canonical entry point is `dqgan figures --id fig2`; this target
//! exists so `cargo bench` regenerates every figure.

fn main() {
    let fast = std::env::var("DQGAN_FAST").map(|v| v != "0").unwrap_or(true);
    if !dqgan::runtime::artifacts_dir().join("manifest.json").exists() {
        eprintln!("SKIP fig2: artifacts not built (run `make artifacts`)");
        return;
    }
    dqgan::exp::images::run(dqgan::exp::images::ImageFigure::Fig2Cifar, fast)
        .expect("fig2 run failed");
}
