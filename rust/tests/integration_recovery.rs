//! Integration: elastic membership and fault recovery
//! (`--on-worker-loss evict`, ADR-005) — a dead worker must not kill
//! the run.
//!
//! Covers, end to end:
//! - mid-run worker death under eviction: the run continues over the
//!   survivors and still converges (inproc chaos injection), and a
//!   worker dead from round 0 produces broadcasts bitwise-identical to
//!   a run where it never existed (TCP socket drop);
//! - rejoin: an evicted worker reconnecting with its old id has the
//!   missed broadcasts replayed bitwise-identically — from the bounded
//!   in-memory ledger, and from the content-addressed checkpoint store
//!   when the gap outruns `--replay-depth`;
//! - the history-hole contract: rejoin with no recoverable history gets
//!   a targeted Shutdown, not a silent gap;
//! - the clean-exit contract (satellite 3): a worker whose transport
//!   dies underneath it — evicted, or the leader simply gone — exits
//!   `worker_loop` cleanly instead of hanging or erroring, on both
//!   transports;
//! - leader recovery: a run whose *leader* dies right after round R's
//!   broadcast (`--chaos-kill-leader`) resumes from the crash-consistent
//!   run manifest with every post-resume round bitwise-identical to an
//!   undisturbed run — inproc via `run_cluster` on both transports, and
//!   over real sockets via the session handshake + reconnect path — and
//!   a config-fingerprint mismatch is refused with a clear error.
//!
//! Everything is gate- or channel-synchronized; no test sleeps.

use dqgan::algo::{AlgoKind, DqganWorker};
use dqgan::comm::{
    inproc_cluster_evloop, inproc_cluster_evloop_with_plan, DelayPlan, Message, MsgKind,
    WorkerEnd,
};
use dqgan::compress::{Compressor, Identity};
use dqgan::config::{
    AggregatorConfig, PolicyConfig, RecoveryConfig, TransportMode, WorkerLossMode,
};
use dqgan::grad::{GradientSource, QuadraticOperator};
use dqgan::optim::LrSchedule;
use dqgan::ckpt::RunManifest;
use dqgan::ps::{run_cluster, serve_rounds_with, worker_loop, ClusterConfig, Decoder};
use dqgan::util::rng::Pcg32;
use std::sync::Arc;

fn identity_decoder() -> Decoder {
    Arc::new(|bytes: &[u8], out: &mut [f32]| Identity.decode_into(bytes, out))
}

fn evict_cfg(policy: PolicyConfig, liveness: u64, recovery: RecoveryConfig) -> AggregatorConfig {
    AggregatorConfig {
        liveness_rounds: liveness,
        recovery,
        ..AggregatorConfig::streaming_with_policy(policy)
    }
}

fn evict_recovery() -> RecoveryConfig {
    RecoveryConfig { on_worker_loss: WorkerLossMode::Evict, ..Default::default() }
}

/// Identity-encoded deterministic payload: same (worker, round) ⇒ same
/// bytes in every run, so survivor averages are bitwise-comparable
/// across cluster sizes.
fn det_payload(worker: u32, round: u64, d: usize) -> Vec<u8> {
    let v = vec![(worker + 1) as f32 * (round + 1) as f32; d];
    let mut wire = Vec::new();
    Identity.encode(&v, &mut wire);
    wire
}

// ---------------------------------------------------------------------
// Mid-run death: the run continues and still converges.
// ---------------------------------------------------------------------

#[test]
fn chaos_kill_mid_run_under_evict_continues_and_converges() {
    // 4 workers, worker 3 drops dead (no teardown handshake) after 5
    // rounds. Under kofm:3 + evict the quorum shrinks to the survivors
    // and error feedback still carries the run to the optimum — the
    // same convergence bar as the all-alive kofm test.
    let cfg = ClusterConfig {
        algo: AlgoKind::parse("dqgan:linf8").unwrap(),
        workers: 4,
        batch: 8,
        rounds: 1200,
        lr: LrSchedule::constant(0.1),
        seed: 11,
        eval_every: 0,
        keep_stats: false,
        agg: evict_cfg(PolicyConfig::KofM { k: 3 }, 2, evict_recovery()),
        transport: TransportMode::EvLoop,
        chaos_kill: Some((3, 5)),
        chaos_kill_leader: None,
        resume: false,
        connect_retry: None,
    };
    let report = run_cluster(&cfg, |_m| {
        let mut rng = Pcg32::new(321);
        Ok(Box::new(QuadraticOperator::new(12, 0.1, &mut rng)))
    })
    .unwrap();
    assert_eq!(report.records.len(), 1200, "the run must complete every round");
    for r in &report.records {
        assert_eq!(r.workers_included, 3, "kofm:3 closes at the quorum (round {})", r.round);
    }
    let rec_last = report.records.last().unwrap();
    assert_eq!(rec_last.workers_evicted, 1, "the dead worker stays evicted to the end");
    assert!(
        report.records.iter().any(|r| r.workers_evicted == 0),
        "eviction must not be retroactive: early rounds ran with full membership"
    );
    let target = {
        let mut rng = Pcg32::new(321);
        QuadraticOperator::new(12, 0.1, &mut rng).target
    };
    let dist = dqgan::util::stats::dist2_sq(&report.worker0.final_params, &target).sqrt();
    assert!(dist < 0.5, "run with a mid-run death must still converge: dist {dist}");
}

#[cfg(unix)]
#[test]
fn tcp_worker_death_under_evict_matches_a_run_without_it() {
    // 3 workers over real sockets; worker 2 registers but never sends a
    // payload and drops its socket (no teardown) once round 0 has
    // closed. Under kofm:2 + evict, every round closes on workers
    // {0, 1}, so the per-round broadcast checksums must be bitwise
    // equal to a 2-worker run where worker 2 never existed. This is
    // the δ-contract soundness argument made executable: partial
    // closes scale by the arrived count, never the configured M.
    use dqgan::comm::tcp::{TcpServerBuilder, TcpWorkerEnd};
    let d = 16usize;
    let rounds = 4u64;
    let fnvs = |recs: &[dqgan::ps::RoundRecord]| -> Vec<(u64, u64)> {
        recs.iter().map(|r| (r.round, r.broadcast_fnv)).collect()
    };

    // ---- Run A: 3 workers, worker 2 dies after round 0.
    let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
    let addr = builder.addr();
    let mut handles = Vec::new();
    for id in [0u32, 1] {
        handles.push(std::thread::spawn(move || {
            let mut w = TcpWorkerEnd::connect_evloop(&addr.to_string(), id).unwrap();
            for round in 0..rounds {
                w.send(Message::payload(id, round, det_payload(id, round, d))).unwrap();
                let b = w.recv().unwrap();
                assert_eq!(b.round, round);
                w.ack(round).unwrap();
            }
            assert_eq!(w.recv().unwrap().kind, MsgKind::Shutdown);
        }));
    }
    let (die_tx, die_rx) = std::sync::mpsc::channel::<()>();
    handles.push(std::thread::spawn(move || {
        let mut w = TcpWorkerEnd::connect_evloop(&addr.to_string(), 2).unwrap();
        // Receive round 0's broadcast (delivered to silent members too),
        // then wait for the leader to have recorded round 0 and drop the
        // socket with no goodbye — a SIGKILL as far as TCP can tell.
        let b = w.recv().unwrap();
        assert_eq!(b.round, 0);
        die_rx.recv().unwrap();
        drop(w);
    }));
    let mut server = builder.accept_evloop(3).unwrap();
    let cfg = evict_cfg(PolicyConfig::KofM { k: 2 }, 0, evict_recovery());
    let mut signaled = false;
    let recs_a = serve_rounds_with(&mut server, identity_decoder(), d, rounds, cfg, |rec| {
        if rec.round == 0 && !signaled {
            signaled = true;
            die_tx.send(()).unwrap();
        }
    })
    .unwrap();
    for h in handles {
        h.join().unwrap();
    }
    drop(server);

    // ---- Run B: 2 workers, worker 2 absent from the start.
    let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
    let addr = builder.addr();
    let handles: Vec<_> = [0u32, 1]
        .into_iter()
        .map(|id| {
            std::thread::spawn(move || {
                let mut w = TcpWorkerEnd::connect_evloop(&addr.to_string(), id).unwrap();
                for round in 0..rounds {
                    w.send(Message::payload(id, round, det_payload(id, round, d))).unwrap();
                    let b = w.recv().unwrap();
                    assert_eq!(b.round, round);
                    w.ack(round).unwrap();
                }
                assert_eq!(w.recv().unwrap().kind, MsgKind::Shutdown);
            })
        })
        .collect();
    let mut server = builder.accept_evloop(2).unwrap();
    let cfg = evict_cfg(PolicyConfig::KofM { k: 2 }, 0, evict_recovery());
    let recs_b =
        serve_rounds_with(&mut server, identity_decoder(), d, rounds, cfg, |_| {}).unwrap();
    for h in handles {
        h.join().unwrap();
    }

    assert_eq!(recs_a.len(), rounds as usize);
    assert_eq!(
        fnvs(&recs_a),
        fnvs(&recs_b),
        "a worker dead since round 0 must be indistinguishable from one never registered"
    );
    assert!(recs_a.iter().all(|r| r.workers_included == 2));
    assert_eq!(
        recs_a.last().unwrap().workers_evicted,
        1,
        "the socket drop must surface as an eviction, not an abort"
    );
    assert!(recs_b.iter().all(|r| r.workers_evicted == 0));
}

// ---------------------------------------------------------------------
// Rejoin: replayed broadcasts are bitwise-identical to the originals.
// ---------------------------------------------------------------------

/// Shared harness for the rejoin tests. Drives a 2-worker inproc
/// evloop cluster for 6 rounds under kofm:1 + liveness 1 + evict:
///
/// - worker 0 feeds every round (its round-4 send is gated so the
///   Rejoin hello provably enters the uplink channel first);
/// - worker 1 sends only round 0, goes silent, is evicted at round 3's
///   liveness check, re-registers with `rejoin(1)` once the eviction is
///   observable, and then collects every downlink frame until Shutdown.
///
/// Returns (per-round records, worker 0's broadcasts, worker 1's
/// post-round-0 frames including the trailing control frame).
fn run_rejoin_scenario(
    recovery: RecoveryConfig,
) -> (Vec<dqgan::ps::RoundRecord>, Vec<Message>, Vec<Message>) {
    let d = 4usize;
    let rounds = 6u64;
    let (mut server, workers, _) = inproc_cluster_evloop(2);
    let mut it = workers.into_iter();
    let mut w0 = it.next().unwrap();
    let mut w1 = it.next().unwrap();
    let (evict_tx, evict_rx) = std::sync::mpsc::channel::<()>();
    let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();

    let t0 = std::thread::spawn(move || {
        let mut broadcasts = Vec::new();
        for round in 0..rounds {
            if round == 4 {
                // Held until worker 1's Rejoin hello is already queued:
                // the uplink is one FIFO channel, so the hello is
                // processed during round 4's gather, before this payload.
                gate_rx.recv().unwrap();
            }
            w0.send(Message::payload(0, round, det_payload(0, round, d))).unwrap();
            loop {
                match w0.recv().unwrap() {
                    b if b.kind == MsgKind::Broadcast || b.kind == MsgKind::PartialBroadcast => {
                        assert_eq!(b.round, round);
                        w0.ack(round).unwrap();
                        broadcasts.push(b);
                        break;
                    }
                    b if b.kind == MsgKind::Shutdown => return broadcasts,
                    _ => {}
                }
            }
        }
        // Drain the trailing Shutdown so teardown is clean.
        let _ = w0.recv();
        broadcasts
    });
    let t1 = std::thread::spawn(move || {
        w1.send(Message::payload(1, 0, det_payload(1, 0, d))).unwrap();
        let b0 = w1.recv().unwrap();
        assert_eq!(b0.round, 0, "worker 1 applies round 0 before going dark");
        w1.ack(0).unwrap();
        // Dark until the leader has provably evicted us...
        evict_rx.recv().unwrap();
        // ...then re-register asking for everything from round 1 on,
        // and only now let worker 0 feed round 4.
        w1.rejoin(1).unwrap();
        gate_tx.send(()).unwrap();
        let mut frames = Vec::new();
        loop {
            match w1.recv() {
                Ok(msg) if msg.kind == MsgKind::Shutdown => {
                    frames.push(msg);
                    return frames;
                }
                Ok(msg)
                    if msg.kind == MsgKind::Broadcast
                        || msg.kind == MsgKind::PartialBroadcast =>
                {
                    let _ = w1.ack(msg.round);
                    frames.push(msg);
                }
                Ok(_) => {}
                Err(_) => return frames,
            }
        }
    });

    let cfg = evict_cfg(PolicyConfig::KofM { k: 1 }, 1, recovery);
    let mut signaled = false;
    let records = serve_rounds_with(&mut server, identity_decoder(), d, rounds, cfg, |rec| {
        if rec.workers_evicted == 1 && !signaled {
            signaled = true;
            evict_tx.send(()).unwrap();
        }
    })
    .unwrap();
    let w0_frames = t0.join().unwrap();
    let w1_frames = t1.join().unwrap();
    drop(server);
    (records, w0_frames, w1_frames)
}

/// Assert every data frame worker 1 received is bitwise-identical to
/// the broadcast worker 0 received for the same round, and return the
/// round sequence of worker 1's data frames.
fn assert_bitwise_against_originals(w0_frames: &[Message], w1_frames: &[Message]) -> Vec<u64> {
    let mut seen = Vec::new();
    for f in w1_frames {
        if f.kind == MsgKind::Shutdown {
            continue;
        }
        let orig = w0_frames
            .iter()
            .find(|b| b.round == f.round)
            .unwrap_or_else(|| panic!("no original broadcast for round {}", f.round));
        assert_eq!(f.kind, orig.kind, "round {}: frame kind drifted in replay", f.round);
        assert_eq!(
            f.payload, orig.payload,
            "round {}: replayed payload is not bitwise-identical",
            f.round
        );
        seen.push(f.round);
    }
    seen
}

#[test]
fn rejoined_worker_replays_missed_broadcasts_bitwise_identically() {
    // Default replay depth (8) covers the whole gap: rounds 1..=3 come
    // from the in-memory ledger. Worker 1's downlink also still holds
    // the round-1/2 originals queued before its eviction — the
    // documented duplicate-delivery race — so those rounds appear
    // twice, and both copies must match worker 0's frames exactly.
    let (records, w0_frames, w1_frames) = run_rejoin_scenario(evict_recovery());
    assert_eq!(records.len(), 6);
    assert_eq!(w0_frames.len(), 6, "worker 0 saw every round");
    assert!(records.iter().all(|r| r.workers_included == 1));
    let by_round = |r: u64| records.iter().find(|rec| rec.round == r).unwrap();
    assert_eq!(by_round(3).workers_evicted, 1, "liveness evicted worker 1 at round 3");
    assert_eq!(by_round(4).workers_evicted, 0, "the rejoin landed during round 4");
    assert_eq!(by_round(5).workers_evicted, 0);

    let seq = assert_bitwise_against_originals(&w0_frames, &w1_frames);
    // Originals queued before eviction (1, 2), the replayed window
    // (1, 2, 3), then the live tail (4, 5) — FIFO order end to end.
    assert_eq!(seq, vec![1, 2, 1, 2, 3, 4, 5], "replay must precede the live broadcast");
    assert_eq!(
        w1_frames.last().map(|m| m.kind),
        Some(MsgKind::Shutdown),
        "the rejoined worker is a member again and gets the normal Shutdown"
    );
    // Monotonic-apply dedup closes the duplicate race: applying rounds
    // strictly in order yields each round exactly once.
    let mut next = 1u64;
    for &r in &seq {
        if r == next {
            next += 1;
        }
    }
    assert_eq!(next, 6, "deduped application covers rounds 1..=5 exactly once");
}

#[test]
fn rejoin_beyond_replay_depth_restores_from_the_checkpoint_store() {
    // replay-depth 1: by rejoin time (round 4) the in-memory window
    // holds only round 3 — rounds 1 and 2 must come back from the
    // content-addressed spill, still bitwise-identical.
    let dir = std::env::temp_dir().join(format!("dqgan_recovery_ckpt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let recovery = RecoveryConfig {
        on_worker_loss: WorkerLossMode::Evict,
        replay_depth: 1,
        ckpt_dir: Some(dir.clone()),
        ckpt_every: 0,
    };
    let (records, w0_frames, w1_frames) = run_rejoin_scenario(recovery);
    assert_eq!(records.len(), 6);
    assert_eq!(records.last().unwrap().workers_evicted, 0, "rejoin succeeded via the store");
    let seq = assert_bitwise_against_originals(&w0_frames, &w1_frames);
    assert_eq!(seq, vec![1, 2, 1, 2, 3, 4, 5]);
    // The store is real on disk: a manifest plus content-addressed
    // blobs for the rotated-out rounds.
    assert!(dir.join("MANIFEST.json").is_file(), "checkpoint manifest written");
    let blobs = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| e.file_name().to_string_lossy().starts_with("bcast-"))
        .count();
    assert!(blobs >= 2, "rounds 1 and 2 were spilled as content-addressed blobs: {blobs}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn rejoin_with_history_hole_and_no_checkpoints_gets_a_clean_shutdown() {
    // replay-depth 1 and no checkpoint store: round 1 is gone by rejoin
    // time. A stale worker must not train across a hole in the
    // broadcast sequence — the leader answers with a targeted Shutdown
    // and keeps the slot evicted.
    let recovery = RecoveryConfig {
        on_worker_loss: WorkerLossMode::Evict,
        replay_depth: 1,
        ckpt_dir: None,
        ckpt_every: 0,
    };
    let (records, w0_frames, w1_frames) = run_rejoin_scenario(recovery);
    assert_eq!(records.len(), 6, "a refused rejoin must not disturb the run");
    assert_eq!(
        records.last().unwrap().workers_evicted,
        1,
        "the slot stays evicted after the refused rejoin"
    );
    // Worker 1 drains the two pre-eviction originals, then the targeted
    // Shutdown — never a frame beyond the hole.
    let seq = assert_bitwise_against_originals(&w0_frames, &w1_frames);
    assert_eq!(seq, vec![1, 2], "only the pre-eviction originals reach the stale worker");
    assert_eq!(
        w1_frames.last().map(|m| m.kind),
        Some(MsgKind::Shutdown),
        "the refusal is an explicit clean Shutdown, not a hang"
    );
}

// ---------------------------------------------------------------------
// Satellite 3: a worker whose transport dies exits cleanly.
// ---------------------------------------------------------------------

fn quad_worker(seed: u64, d: usize) -> (DqganWorker, QuadraticOperator) {
    let mut rng = Pcg32::new(seed);
    let src = QuadraticOperator::new(d, 0.0, &mut rng);
    let w0 = {
        let mut rng = Pcg32::new(seed ^ 0x5EED);
        src.init_params(&mut rng)
    };
    (DqganWorker::new(w0, LrSchedule::constant(0.1), Arc::new(Identity)), src)
}

#[test]
fn worker_loop_exits_cleanly_when_the_leader_vanishes_mid_recv_inproc() {
    // Regression: the phase-2 recv used to propagate the transport
    // error. The leader consumes the payload, then disappears without a
    // Shutdown — the worker must return Ok with 0 completed rounds.
    use dqgan::comm::ServerEnd;
    let d = 6usize;
    let (mut server, worker_ends, _) = inproc_cluster_evloop(1);
    let mut end = worker_ends.into_iter().next().unwrap();
    let h = std::thread::spawn(move || {
        let (mut algo, mut src) = quad_worker(91, d);
        let mut rng = Pcg32::new(17);
        worker_loop(&mut end, &mut algo, &mut src, 4, 3, &mut rng, false, None)
    });
    // Read the round-0 payload so the worker is provably blocked in its
    // phase-2 recv, then vanish.
    let msgs = server.recv_round().unwrap();
    assert_eq!(msgs[0].kind, MsgKind::Payload);
    drop(server);
    let summary = h.join().unwrap().expect("dead transport mid-recv must be a clean exit");
    assert_eq!(summary.rounds, 0, "no broadcast ever arrived");
}

#[cfg(unix)]
#[test]
fn worker_loop_exits_cleanly_when_the_leader_vanishes_mid_recv_tcp() {
    // Same contract over a real socket: EOF in the phase-2 recv is a
    // clean exit, not an error and not a hang.
    use dqgan::comm::tcp::{TcpServerBuilder, TcpWorkerEnd};
    use dqgan::comm::ServerEnd;
    let d = 6usize;
    let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
    let addr = builder.addr();
    let h = std::thread::spawn(move || {
        let mut end = TcpWorkerEnd::connect(&addr.to_string(), 0).unwrap();
        let (mut algo, mut src) = quad_worker(92, d);
        let mut rng = Pcg32::new(18);
        worker_loop(&mut end, &mut algo, &mut src, 4, 3, &mut rng, false, None)
    });
    let mut server = builder.accept(1).unwrap();
    let msgs = server.recv_round().unwrap();
    assert_eq!(msgs[0].kind, MsgKind::Payload);
    drop(server);
    let summary = h.join().unwrap().expect("socket EOF mid-recv must be a clean exit");
    assert_eq!(summary.rounds, 0);
}

#[test]
fn evicted_inproc_worker_rides_out_the_run_and_exits_on_shutdown() {
    // Full worker_loop under eviction, inproc flavor: worker 1's
    // round-1 send is gated until after its liveness eviction. Once
    // released it drains the two broadcasts queued before the eviction
    // (staying in lockstep that far), blocks on its muted downlink, and
    // exits cleanly on the run-end Shutdown — which eviction still
    // delivers — while the leader closes all 6 rounds on worker 0.
    let d = 8usize;
    let rounds = 6u64;
    let plan = DelayPlan::new();
    plan.hold(1, 1);
    let (mut server, worker_ends, _) = inproc_cluster_evloop_with_plan(2, plan.clone());
    let handles: Vec<_> = worker_ends
        .into_iter()
        .enumerate()
        .map(|(m, mut end)| {
            std::thread::spawn(move || {
                let (mut algo, mut src) = quad_worker(40 + m as u64, d);
                let mut rng = Pcg32::new(60 + m as u64);
                worker_loop(&mut end, &mut algo, &mut src, 4, rounds, &mut rng, false, None)
            })
        })
        .collect();
    let cfg = evict_cfg(PolicyConfig::KofM { k: 1 }, 1, evict_recovery());
    let mut released = false;
    let recs = serve_rounds_with(&mut server, identity_decoder(), d, rounds, cfg, |rec| {
        if rec.workers_evicted == 1 && !released {
            released = true;
            plan.release(1, 1);
        }
    })
    .unwrap();
    assert_eq!(recs.len(), rounds as usize);
    assert!(recs.iter().all(|r| r.workers_included == 1));
    assert_eq!(recs.last().unwrap().workers_evicted, 1);
    drop(server); // unblocks worker 1's trailing recv
    let summaries: Vec<_> =
        handles.into_iter().map(|h| h.join().unwrap().expect("clean exit")).collect();
    assert_eq!(summaries[0].rounds, rounds, "the survivor completes the whole run");
    assert_eq!(
        summaries[1].rounds, 3,
        "the evicted worker applied rounds 0..=2 (queued pre-eviction) and no more"
    );
}

// ---------------------------------------------------------------------
// Leader recovery: crash-consistent resume across a leader kill.
// ---------------------------------------------------------------------

#[test]
fn leader_kill_then_resume_is_bitwise_identical_on_both_transports() {
    // `--chaos-kill-leader 12` under ckpt cadence 5: the leader dies
    // right after round 12's broadcast, the manifest points at round 9
    // (the newest snapshot round all three workers had durably
    // recorded), and `--resume` serves rounds 10..20 bitwise-identical
    // to a run that was never disturbed — on both transports.
    for transport in [TransportMode::EvLoop, TransportMode::Threads] {
        let dir = std::env::temp_dir().join(format!(
            "dqgan_leader_kill_{transport:?}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let build = |resume: bool, chaos: Option<u64>, ckpt: bool, lr: f32| ClusterConfig {
            algo: AlgoKind::parse("dqgan:linf8").unwrap(),
            workers: 3,
            batch: 8,
            rounds: 20,
            lr: LrSchedule::constant(lr),
            seed: 77,
            eval_every: 0,
            keep_stats: false,
            agg: AggregatorConfig {
                recovery: RecoveryConfig {
                    ckpt_dir: ckpt.then(|| dir.clone()),
                    ckpt_every: if ckpt { 5 } else { 0 },
                    ..RecoveryConfig::default()
                },
                ..AggregatorConfig::pipelined()
            },
            transport,
            chaos_kill: None,
            chaos_kill_leader: chaos,
            resume,
            connect_retry: None,
        };
        let run = |cfg: &ClusterConfig| {
            run_cluster(cfg, |_m| {
                let mut rng = Pcg32::new(4040);
                Ok(Box::new(QuadraticOperator::new(10, 0.1, &mut rng)))
            })
        };
        let baseline = run(&build(false, None, false, 0.05)).unwrap();
        assert_eq!(baseline.records.len(), 20);
        let killed = run(&build(false, Some(12), true, 0.05)).unwrap();
        assert_eq!(killed.records.last().unwrap().round, 12, "no rounds served past the kill");
        let man = RunManifest::load(&dir).unwrap().expect("manifest survives the kill");
        assert_eq!(man.round, 9, "cadence 5 ⇒ rounds 4, 9, 14; 9 is the newest complete");
        assert_eq!(man.epoch, 0);
        assert_eq!(man.workers, 3);
        // A config-fingerprint mismatch (different step size) is refused
        // with a clear error before anything is restored.
        let err = run(&build(true, None, true, 0.07)).unwrap_err();
        assert!(
            err.to_string().contains("fingerprint mismatch"),
            "{transport:?}: unexpected refusal error: {err}"
        );
        // The honest resume continues at round 10 under epoch 1.
        let resumed = run(&build(true, None, true, 0.05)).unwrap();
        assert_eq!(resumed.records.first().unwrap().round, man.round + 1);
        assert_eq!(resumed.records.last().unwrap().round, 19);
        for rec in &resumed.records {
            let base = &baseline.records[rec.round as usize];
            assert_eq!(
                (rec.round, rec.broadcast_fnv),
                (base.round, base.broadcast_fnv),
                "{transport:?}: post-resume round {} must be bitwise identical",
                rec.round
            );
        }
        assert_eq!(
            resumed.worker0.final_params, baseline.worker0.final_params,
            "{transport:?}: final parameters must be bitwise identical after resume"
        );
        let man2 = RunManifest::load(&dir).unwrap().unwrap();
        assert_eq!(man2.epoch, 1, "resume bumps the session epoch");
        assert_eq!(man2.round, 19, "run end publishes the last snapshot round");
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[cfg(unix)]
#[test]
fn tcp_leader_kill_session_reconnect_resumes_bitwise_identically() {
    // The full over-the-wire recovery story: a session leader dies after
    // round 3 (no Shutdown — its sockets just close), a second
    // incarnation reloads the manifest from disk, re-listens on a fresh
    // port, and the fleet re-attaches via the Hello/Welcome handshake
    // with a connect-retry policy. Rounds before the kill and after the
    // resume must both be bitwise-identical to an undisturbed run.
    use dqgan::ckpt::CkptStore;
    use dqgan::comm::tcp::{TcpServerBuilder, TcpWorkerEnd};
    use dqgan::comm::{RetryPolicy, SessionInfo};
    use dqgan::ps::{serve_rounds_session, ServeSession};
    use std::sync::Mutex;

    const FP: u64 = 0xFEED_FACE_2020_1359;
    let d = 8usize;
    let rounds = 8u64;
    let fnvs = |recs: &[dqgan::ps::RoundRecord]| -> Vec<(u64, u64)> {
        recs.iter().map(|r| (r.round, r.broadcast_fnv)).collect()
    };
    let dir =
        std::env::temp_dir().join(format!("dqgan_tcp_leader_kill_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // ---- Undisturbed baseline.
    let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
    let addr = builder.addr();
    let handles: Vec<_> = [0u32, 1]
        .into_iter()
        .map(|id| {
            std::thread::spawn(move || {
                let mut w = TcpWorkerEnd::connect_evloop(&addr.to_string(), id).unwrap();
                for round in 0..rounds {
                    w.send(Message::payload(id, round, det_payload(id, round, d))).unwrap();
                    let b = w.recv().unwrap();
                    assert_eq!(b.round, round);
                    w.ack(round).unwrap();
                }
                assert_eq!(w.recv().unwrap().kind, MsgKind::Shutdown);
            })
        })
        .collect();
    let mut server = builder.accept_evloop(2).unwrap();
    let base = serve_rounds_with(
        &mut server,
        identity_decoder(),
        d,
        rounds,
        AggregatorConfig::pipelined(),
        |_| {},
    )
    .unwrap();
    for h in handles {
        h.join().unwrap();
    }
    drop(server);

    // ---- Incarnation 1: session leader, "killed" after round 3.
    let store = Arc::new(Mutex::new(CkptStore::open(&dir).unwrap()));
    let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
    let addr1 = builder.addr();
    let mut handles = Vec::new();
    let mut addr_txs = Vec::new();
    for id in [0u32, 1] {
        let (tx, rx) = std::sync::mpsc::channel::<String>();
        addr_txs.push(tx);
        handles.push(std::thread::spawn(move || {
            // Fresh session: epoch 0, serving from round 0.
            let (mut w, welcome) =
                TcpWorkerEnd::connect_session(&addr1.to_string(), id, FP, 0, None, true)
                    .unwrap();
            assert_eq!(welcome.epoch, 0);
            assert_eq!(welcome.resume_round, 0);
            let mut round = welcome.resume_round;
            loop {
                if w.send(Message::payload(id, round, det_payload(id, round, d))).is_err() {
                    break; // leader died mid-uplink
                }
                match w.recv() {
                    Ok(b) if b.kind == MsgKind::Broadcast => {
                        assert_eq!(b.round, round);
                        let _ = w.ack(round);
                        round += 1;
                    }
                    // Dead leader: the socket closed with no Shutdown.
                    _ => break,
                }
            }
            drop(w);
            // The restarted leader listens on a new address: reconnect
            // with backoff, announce the last epoch we saw, and resume
            // exactly where its Welcome says.
            let addr2 = rx.recv().unwrap();
            let retry = RetryPolicy { attempts: 5, base_ms: 1 };
            let (mut w, welcome) =
                TcpWorkerEnd::connect_session(&addr2, id, FP, 0, Some(retry), true).unwrap();
            assert_eq!(welcome.epoch, 1, "restarted leader bumps the session epoch");
            assert_eq!(welcome.resume_round, 4, "resume at manifest round + 1");
            for round in welcome.resume_round..rounds {
                w.send(Message::payload(id, round, det_payload(id, round, d))).unwrap();
                let b = w.recv().unwrap();
                assert_eq!(b.round, round);
                w.ack(round).unwrap();
            }
            assert_eq!(w.recv().unwrap().kind, MsgKind::Shutdown);
        }));
    }
    let mut server = builder
        .accept_evloop_session(2, SessionInfo { epoch: 0, fingerprint: FP, resume_round: 0 })
        .unwrap();
    let sess = ServeSession {
        start_round: 0,
        chaos_kill_leader: Some(3),
        store: Some(store.clone()),
        snapshot_every: Some(2),
    };
    let recs1 = serve_rounds_session(
        &mut server,
        identity_decoder(),
        d,
        rounds,
        AggregatorConfig::pipelined(),
        sess,
        |_| {},
    )
    .unwrap();
    assert_eq!(recs1.last().unwrap().round, 3);
    drop(server); // the kill: sockets close, no Shutdown was ever sent
    // Crash-consistent state on disk: snapshot rounds 1 and 3 were
    // spilled *before* their broadcasts went out. Publish the manifest a
    // full run would have advanced (these identity workers carry no
    // state, so no wstate blobs gate it here — the stateful flavor is
    // covered by the run_cluster tests above).
    {
        let st = store.lock().unwrap();
        assert!(st.contains("bcast", 1, 0) && st.contains("bcast", 3, 0));
        RunManifest {
            round: 3,
            epoch: 0,
            fingerprint: FP,
            workers: 2,
            worker_digests: Vec::new(),
            replay_rounds: st.rounds("bcast"),
        }
        .save(st.dir())
        .unwrap();
    }
    drop(store);

    // ---- Incarnation 2: a "restarted process" — reload everything from
    // disk, re-listen on a fresh port, wait for the fleet to re-attach.
    let man = RunManifest::load(&dir).unwrap().expect("manifest on disk");
    assert_eq!(man.fingerprint, FP);
    let store = Arc::new(Mutex::new(CkptStore::open(&dir).unwrap()));
    let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
    let addr2 = builder.addr().to_string();
    for tx in addr_txs {
        tx.send(addr2.clone()).unwrap();
    }
    let mut server = builder
        .accept_evloop_session(
            2,
            SessionInfo { epoch: man.epoch + 1, fingerprint: FP, resume_round: man.round + 1 },
        )
        .unwrap();
    let sess = ServeSession {
        start_round: man.round + 1,
        chaos_kill_leader: None,
        store: Some(store.clone()),
        snapshot_every: Some(2),
    };
    let recs2 = serve_rounds_session(
        &mut server,
        identity_decoder(),
        d,
        rounds,
        AggregatorConfig::pipelined(),
        sess,
        |_| {},
    )
    .unwrap();
    for h in handles {
        h.join().unwrap();
    }
    drop(server);

    assert_eq!(fnvs(&recs1), fnvs(&base[..4]), "pre-kill rounds match the undisturbed run");
    assert_eq!(fnvs(&recs2), fnvs(&base[4..]), "post-resume rounds match the undisturbed run");
    std::fs::remove_dir_all(&dir).ok();
}

#[cfg(unix)]
#[test]
fn evicted_tcp_worker_exits_cleanly_on_its_closed_socket() {
    // TCP flavor: the eviction closes worker 1's socket while it is
    // gated mid-send. Whichever way the race lands — the write fails
    // (drain path) or succeeds into the doomed socket (phase-2 recv
    // path) — worker_loop must return Ok, never hang and never Err.
    use dqgan::comm::tcp::{TcpServerBuilder, TcpWorkerEnd};
    let d = 8usize;
    let rounds = 6u64;
    let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
    let addr = builder.addr();
    let plan = DelayPlan::new();
    plan.hold(1, 1);
    let wplan = plan.clone();
    let h0 = std::thread::spawn(move || {
        let mut end = TcpWorkerEnd::connect_evloop(&addr.to_string(), 0).unwrap();
        let (mut algo, mut src) = quad_worker(50, d);
        let mut rng = Pcg32::new(70);
        worker_loop(&mut end, &mut algo, &mut src, 4, rounds, &mut rng, false, None)
    });
    let h1 = std::thread::spawn(move || {
        let mut end =
            TcpWorkerEnd::connect_evloop_with_plan(&addr.to_string(), 1, Some(wplan)).unwrap();
        let (mut algo, mut src) = quad_worker(51, d);
        let mut rng = Pcg32::new(71);
        worker_loop(&mut end, &mut algo, &mut src, 4, rounds, &mut rng, false, None)
    });
    let mut server = builder.accept_evloop(2).unwrap();
    let cfg = evict_cfg(PolicyConfig::KofM { k: 1 }, 1, evict_recovery());
    let mut released = false;
    let recs = serve_rounds_with(&mut server, identity_decoder(), d, rounds, cfg, |rec| {
        if rec.workers_evicted == 1 && !released {
            released = true;
            plan.release(1, 1);
        }
    })
    .unwrap();
    assert_eq!(recs.len(), rounds as usize);
    assert_eq!(recs.last().unwrap().workers_evicted, 1);
    let s0 = h0.join().unwrap().expect("survivor finishes normally");
    assert_eq!(s0.rounds, rounds);
    let s1 = h1.join().unwrap().expect("evicted worker must exit cleanly, not error");
    assert!(
        (1..=3).contains(&s1.rounds),
        "applied round 0, plus whatever pre-eviction broadcasts survived the RST race: {}",
        s1.rounds
    );
}
