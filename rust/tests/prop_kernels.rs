//! Scalar-vs-SIMD bitwise-equality property suite.
//!
//! The kernel layer's contract (`src/kernels/`) is that the `--kernels
//! scalar` and `--kernels simd` arms of every hot loop evaluate the
//! **identical** per-element IEEE-754 expressions in the **identical**
//! order, so outputs match bit for bit — not approximately, exactly.
//! These tests pin that contract at the subsystem level (full codec
//! wire round trips, aggregator rounds, message frames), on top of the
//! per-kernel unit tests, over ragged dimensions (1, 7, 8, 9, shard±1)
//! and adversarial payloads: −0.0, NaN with a nonzero payload, and
//! subnormals.

use dqgan::comm::Message;
use dqgan::compress::{compressor_from_spec, Compressor};
use dqgan::config::{AggMode, AggregatorConfig, KernelMode, ReduceMode};
use dqgan::kernels;
use dqgan::ps::{Aggregator, Decoder};
use dqgan::testutil::forall;
use dqgan::util::bytes::{fnv1a64_f32, put_f32_slice};
use dqgan::util::rng::Pcg32;
use dqgan::{prop_assert, prop_pass};
use std::sync::Arc;

/// Every codec with a SIMD arm, plus identity/topk (mode-independent by
/// construction — included so a future arm can't silently diverge).
const SPECS: &[&str] = &[
    "identity",
    "qsgd8",
    "qsgd(s=3)",
    "linf8",
    "linf(s=7)",
    "linf(bits=8,block=64)",
    "sign",
    "terngrad",
    "topk(f=0.3)",
];

/// Lane count is 8: cover below/at/above one chunk, two chunks, the
/// sign/terngrad word sizes (32 / 16 symbols), and ragged tails of each.
const DIMS: &[usize] = &[1, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 65, 127, 128, 129, 257];

/// IEEE-754 edge cases the lane chunking must not canonicalize away.
const SPECIALS: &[f32] = &[
    -0.0,
    f32::from_bits(0x7FC0_1234), // quiet NaN with a nonzero payload
    f32::from_bits(0x0000_0001), // smallest positive subnormal
    f32::from_bits(0x8000_0007), // negative subnormal
    f32::MIN_POSITIVE,
    -1.0e-38,
];

/// A normal vector with specials scattered at rng-chosen positions.
fn special_vec(d: usize, rng: &mut Pcg32) -> Vec<f32> {
    let mut v: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
    for &s in SPECIALS {
        let i = rng.below(d as u32) as usize;
        v[i] = s;
    }
    v
}

/// Like [`special_vec`] but finite-only (−0.0 and subnormals, no NaN):
/// the aggregator deliberately rejects non-finite payloads, so its A/B
/// must stay inside the accepted input domain.
fn finite_special_vec(d: usize, rng: &mut Pcg32) -> Vec<f32> {
    let mut v = special_vec(d, rng);
    for x in v.iter_mut() {
        if !x.is_finite() {
            *x = -0.0;
        }
    }
    v
}

/// Every codec: wire bytes, dense quantized output and both-mode decodes
/// are bit-identical between the scalar and SIMD arms (same rng seed ⇒
/// same stochastic rounding draws in element order).
#[test]
fn prop_codec_arms_bit_identical() {
    forall("codec scalar≡simd", 150, |g| {
        let spec = *g.choose(SPECS);
        let c = compressor_from_spec(spec).unwrap();
        let d = *g.choose(DIMS);
        let seed = g.rng().next_u64();
        let v = special_vec(d, g.rng());
        let run = |mode: KernelMode| {
            let _guard = kernels::scoped_mode(mode);
            let mut rng = Pcg32::new(seed);
            let mut buf = Vec::new();
            let q = c.compress_encoded(&v, &mut rng, &mut buf);
            (q, buf)
        };
        let (q_s, wire_s) = run(KernelMode::Scalar);
        let (q_v, wire_v) = run(KernelMode::Simd);
        prop_assert!(wire_s == wire_v, "{spec} d={d}: wire bytes differ between arms");
        for i in 0..d {
            prop_assert!(
                q_s[i].to_bits() == q_v[i].to_bits(),
                "{spec} d={d}: quantized bit mismatch at {i}: {:#010x} vs {:#010x}",
                q_s[i].to_bits(),
                q_v[i].to_bits()
            );
        }
        // Decode the (shared) wire under each mode: the two arms must
        // agree bit for bit. (Decode ≡ quantized round-trip fidelity is
        // a separate property — prop_compressors.rs — that NaN inputs
        // legitimately break for sign-bit codecs; the arm-equality
        // contract must hold even there.)
        let dec = |mode: KernelMode| {
            let _guard = kernels::scoped_mode(mode);
            let mut out = vec![0.0f32; d];
            c.decode_into(&wire_s, &mut out).unwrap();
            out
        };
        let out_s = dec(KernelMode::Scalar);
        let out_v = dec(KernelMode::Simd);
        for i in 0..d {
            prop_assert!(
                out_s[i].to_bits() == out_v[i].to_bits(),
                "{spec} d={d}: decode bit mismatch between arms at {i}: {:#010x} vs {:#010x}",
                out_s[i].to_bits(),
                out_v[i].to_bits()
            );
        }
        prop_pass!()
    });
}

/// Truncated wires must error under both arms (error text may differ;
/// fabricating output from a short buffer must not).
#[test]
fn prop_codec_arms_agree_on_truncation() {
    forall("codec truncation scalar≡simd", 80, |g| {
        let spec = *g.choose(SPECS);
        let c = compressor_from_spec(spec).unwrap();
        let d = g.usize_in(4..=200);
        let v = g.vec_normal(d..=d);
        let mut wire = Vec::new();
        let _ = c.compress_encoded(&v, g.rng(), &mut wire);
        if wire.len() < 2 {
            prop_pass!();
        }
        let cut = g.usize_in(0..=wire.len().saturating_sub(2));
        for mode in [KernelMode::Scalar, KernelMode::Simd] {
            let _guard = kernels::scoped_mode(mode);
            let mut out = vec![0.0f32; d];
            prop_assert!(
                c.decode_into(&wire[..cut], &mut out).is_err(),
                "{spec} d={d} mode={mode:?}: decoded from {cut}/{} bytes",
                wire.len()
            );
        }
        prop_pass!()
    });
}

/// Full aggregator rounds (decode → shard fold → scale) produce
/// bit-identical averages and round checksums under both kernel arms,
/// across engines and shard sizes that straddle the lane width.
#[test]
fn prop_aggregator_rounds_bit_identical_across_arms() {
    forall("aggregate scalar≡simd", 40, |g| {
        let workers = g.usize_in(1..=5);
        let shard = *g.choose(&[1usize, 7, 8, 9, 16, 64]);
        // Dims around shard multiples: shard−1, shard, shard+1 regimes.
        let d = {
            let k = g.usize_in(1..=4);
            let base = shard * k;
            *g.choose(&[base.saturating_sub(1).max(1), base, base + 1])
        };
        let agg_mode = *g.choose(&[AggMode::Sequential, AggMode::Sharded, AggMode::Streaming]);
        let reduce = *g.choose(&[ReduceMode::Windowed, ReduceMode::Barrier]);
        let codec = compressor_from_spec("linf8").unwrap();
        let wires: Vec<Vec<u8>> = (0..workers)
            .map(|_| {
                let v = finite_special_vec(d, g.rng());
                let mut wire = Vec::new();
                codec.compress_encoded(&v, g.rng(), &mut wire);
                wire
            })
            .collect();
        let decoder: Decoder = {
            let c = compressor_from_spec("linf8").unwrap();
            Arc::new(move |bytes: &[u8], out: &mut [f32]| c.decode_into(bytes, out))
        };
        let run = |mode: KernelMode| {
            let _guard = kernels::scoped_mode(mode);
            let mut agg = Aggregator::new(
                AggregatorConfig {
                    mode: agg_mode,
                    shard_elems: shard,
                    reduce,
                    ..Default::default()
                },
                d,
                workers,
            );
            let msgs: Vec<Message> = wires
                .iter()
                .enumerate()
                .map(|(w, wire)| Message::payload(w as u32, 0, wire.clone()))
                .collect();
            let avg = agg.aggregate(0, &msgs, &decoder).unwrap();
            let bits: Vec<u32> = avg.iter().map(|x| x.to_bits()).collect();
            let fnv = fnv1a64_f32(avg);
            (bits, fnv)
        };
        let (bits_s, fnv_s) = run(KernelMode::Scalar);
        let (bits_v, fnv_v) = run(KernelMode::Simd);
        prop_assert!(
            bits_s == bits_v,
            "avg bits differ: d={d} shard={shard} M={workers} {agg_mode:?}/{reduce:?}"
        );
        prop_assert!(fnv_s == fnv_v, "broadcast_fnv differs between arms");
        prop_pass!()
    });
}

/// Serialization + checksum building blocks: `put_f32_slice`,
/// `fnv1a64_f32` and whole message frames are byte-identical across
/// arms, and frames encoded under one arm decode under the other.
#[test]
fn prop_frame_bytes_mode_invariant() {
    forall("frame scalar≡simd", 80, |g| {
        let d = *g.choose(DIMS);
        let v = special_vec(d, g.rng());
        let run = |mode: KernelMode| {
            let _guard = kernels::scoped_mode(mode);
            let mut buf = Vec::new();
            put_f32_slice(&mut buf, &v);
            (buf, fnv1a64_f32(&v))
        };
        let (bytes_s, fnv_s) = run(KernelMode::Scalar);
        let (bytes_v, fnv_v) = run(KernelMode::Simd);
        prop_assert!(bytes_s == bytes_v, "put_f32_slice differs at d={d}");
        prop_assert!(fnv_s == fnv_v, "fnv1a64_f32 differs at d={d}");

        // Frame CRC: byte-at-a-time vs slicing-by-8, cross-mode decode.
        let n_payload = g.usize_in(0..=300);
        let payload: Vec<u8> = (0..n_payload).map(|_| g.rng().next_u32() as u8).collect();
        let msg = Message::payload(2, 9, payload);
        let frame_s = {
            let _guard = kernels::scoped_mode(KernelMode::Scalar);
            msg.encode()
        };
        let frame_v = {
            let _guard = kernels::scoped_mode(KernelMode::Simd);
            msg.encode()
        };
        prop_assert!(frame_s == frame_v, "frame bytes differ between arms");
        for mode in [KernelMode::Scalar, KernelMode::Simd] {
            let _guard = kernels::scoped_mode(mode);
            let back = Message::decode(&frame_s);
            prop_assert!(back.is_ok(), "cross-mode frame decode failed under {mode:?}");
        }
        prop_pass!()
    });
}

/// The fold kernels themselves (the `fold_shard`/`close_shard` inner
/// loops) over ragged lengths with specials: one shot per dim, both
/// directions, no aggregator plumbing.
#[test]
fn prop_fold_kernels_bit_identical() {
    forall("fold kernels scalar≡simd", 60, |g| {
        let d = *g.choose(DIMS);
        let a0 = special_vec(d, g.rng());
        let src = special_vec(d, g.rng());
        let k = *g.choose(&[0.125f32, 0.5, 1.0 / 3.0, 1.0e30, -0.0]);
        let run = |mode: KernelMode| {
            let _guard = kernels::scoped_mode(mode);
            let mut acc = a0.clone();
            kernels::add_assign(&mut acc, &src);
            let mut out = vec![0.0f32; d];
            kernels::scale_into(&mut out, &acc, k);
            kernels::scale_in_place(&mut acc, k);
            let levels: Vec<i32> = (0..d).map(|i| i as i32 % 255 - 127).collect();
            let mut grid = vec![0.0f32; d];
            kernels::grid_reconstruct(&mut grid, &levels, k, 127.0);
            (acc, out, grid)
        };
        let (acc_s, out_s, grid_s) = run(KernelMode::Scalar);
        let (acc_v, out_v, grid_v) = run(KernelMode::Simd);
        for i in 0..d {
            prop_assert!(
                acc_s[i].to_bits() == acc_v[i].to_bits()
                    && out_s[i].to_bits() == out_v[i].to_bits()
                    && grid_s[i].to_bits() == grid_v[i].to_bits(),
                "fold kernel bit mismatch at {i} (d={d}, k={k})"
            );
        }
        prop_pass!()
    });
}
