//! Integration: the round-completion policy subsystem — K-of-M partial
//! aggregation, deadline grace windows, late-frame draining, inclusion
//! bitmaps and error-feedback re-absorption — over scripted stragglers
//! ([`DelayPlan`] gates, channel-synchronized TCP holds), never sleeps.

use dqgan::algo::{AlgoKind, DqganWorker, WorkerAlgo};
use dqgan::comm::tcp::{TcpServerBuilder, TcpWorkerEnd};
use dqgan::comm::{
    inproc_cluster, inproc_cluster_with_plan, read_inclusion_bitmap, DelayPlan, Message,
    MsgKind, WorkerEnd,
};
use dqgan::compress::{compressor_from_spec, Compressor, Identity};
use dqgan::config::{AggMode, AggregatorConfig, PolicyConfig};
use dqgan::grad::{GradientSource, QuadraticOperator};
use dqgan::optim::LrSchedule;
use dqgan::ps::{
    run_cluster, serve_rounds, serve_rounds_with, worker_loop, Aggregator, ClusterConfig,
    Decoder,
};
use dqgan::tensor::ops;
use dqgan::util::bytes::Reader;
use dqgan::util::rng::Pcg32;
use std::sync::Arc;

fn identity_decoder() -> Decoder {
    Arc::new(|bytes: &[u8], out: &mut [f32]| Identity.decode_into(bytes, out))
}

fn quad_src(m: usize, d: usize) -> QuadraticOperator {
    let mut rng = Pcg32::new(500 + m as u64);
    QuadraticOperator::new(d, 0.0, &mut rng)
}

#[test]
fn full_policy_keeps_the_plain_broadcast_frame_and_includes_everyone() {
    // `--policy full` must stay bitwise-identical to today's streaming
    // output — including the frame kind on the wire (no bitmap header).
    let d = 4;
    let (mut server, mut workers, _) = inproc_cluster(2);
    for (i, w) in workers.iter_mut().enumerate() {
        let mut wire = Vec::new();
        Identity.encode(&[i as f32; 4], &mut wire);
        w.send(Message::payload(i as u32, 0, wire)).unwrap();
    }
    let t = std::thread::spawn(move || {
        let mut avgs = Vec::new();
        for w in &mut workers {
            let b = w.recv().unwrap();
            assert_eq!(b.kind, MsgKind::Broadcast, "full policy must not add a bitmap");
            avgs.push(Identity.decode(&b.payload, d).unwrap());
            assert_eq!(w.recv().unwrap().kind, MsgKind::Shutdown);
        }
        avgs
    });
    let cfg = AggregatorConfig::streaming_with_policy(PolicyConfig::Full);
    let recs = serve_rounds_with(&mut server, identity_decoder(), d, 1, cfg, |_| {}).unwrap();
    assert_eq!(recs[0].workers_included, 2);
    assert_eq!(recs[0].workers_skipped, 0);
    let avgs = t.join().unwrap();
    assert_eq!(avgs[0], vec![0.5; 4]);
    assert_eq!(avgs[0], avgs[1]);
}

#[test]
fn full_policy_cluster_is_bitwise_identical_to_sequential() {
    let run = |agg: AggregatorConfig| {
        let cfg = ClusterConfig {
            algo: AlgoKind::parse("dqgan:linf8").unwrap(),
            workers: 4,
            batch: 8,
            rounds: 40,
            lr: LrSchedule::constant(0.05),
            seed: 42,
            eval_every: 0,
            keep_stats: false,
            agg,
            transport: Default::default(),
            chaos_kill: None,
        };
        run_cluster(&cfg, |_m| {
            let mut rng = Pcg32::new(7);
            Ok(Box::new(QuadraticOperator::new(64, 0.1, &mut rng)))
        })
        .unwrap()
    };
    let seq = run(AggregatorConfig::sequential());
    let full = run(AggregatorConfig::streaming_with_policy(PolicyConfig::Full));
    assert_eq!(seq.worker0.final_params, full.worker0.final_params);
    for r in &full.records {
        assert_eq!((r.workers_included, r.workers_skipped), (4, 0));
    }
}

#[test]
fn kofm_broadcast_equals_the_mean_of_exactly_the_included_slots() {
    // Property: over qsgd/sign/topk wire payloads, random inclusion
    // subsets and scrambled arrival orders, a partial round's output is
    // bitwise the `mean_into` of the included slots in worker-id order.
    let mut rng = Pcg32::new(0xBEEF_2026);
    for spec in ["qsgd8", "sign", "topk(f=0.1)"] {
        let c = compressor_from_spec(spec).unwrap();
        for &m in &[4usize, 8] {
            for &d in &[63usize, 1024, 4096] {
                let msgs: Vec<Message> = (0..m)
                    .map(|w| {
                        let v = rng.normal_vec(d);
                        let mut wire = Vec::new();
                        c.compress_encoded(&v, &mut rng, &mut wire);
                        Message::payload(w as u32, 5, wire)
                    })
                    .collect();
                let dec: Decoder = {
                    let c = compressor_from_spec(spec).unwrap();
                    Arc::new(move |b: &[u8], out: &mut [f32]| c.decode_into(b, out))
                };
                // Random subset of size 1..=m, accepted in shuffled order.
                let k = 1 + rng.below(m as u32) as usize;
                let mut ids: Vec<usize> = (0..m).collect();
                rng.shuffle(&mut ids);
                let included = &ids[..k];
                let mut agg = Aggregator::new(
                    AggregatorConfig {
                        mode: AggMode::Streaming,
                        threads: 3,
                        shard_elems: 256,
                        ..Default::default()
                    },
                    d,
                    m,
                );
                agg.begin_round(5);
                for &w in included {
                    agg.accept(&msgs[w], &dec).unwrap();
                }
                let avg = agg.finish_partial().unwrap();
                let mut sorted = included.to_vec();
                sorted.sort_unstable();
                let decoded: Vec<Vec<f32>> =
                    sorted.iter().map(|&w| c.decode(&msgs[w].payload, d).unwrap()).collect();
                let refs: Vec<&[f32]> = decoded.iter().map(|v| v.as_slice()).collect();
                let mut oracle = vec![0.0f32; d];
                ops::mean_into(&refs, &mut oracle);
                for i in 0..d {
                    assert_eq!(
                        oracle[i].to_bits(),
                        avg[i].to_bits(),
                        "{spec} M={m} d={d} K={k}: element {i} differs"
                    );
                }
            }
        }
    }
}

#[test]
fn kofm_skipped_worker_reabsorbs_its_payload_and_stays_in_lockstep() {
    // Gate-based (no sleeps): worker 1's round-0 frame is held, kofm:1
    // closes the round on worker 0 alone, and worker 1 — told by the
    // inclusion bitmap — folds its entire sent payload into its error
    // memory (norm grows from 0 to ‖p̂‖ exactly, Identity compressor).
    let d = 12usize;
    let batch = 4usize;
    let lr = LrSchedule::constant(0.1);
    let plan = DelayPlan::new();
    plan.hold(1, 0);
    let (mut server, worker_ends, _) = inproc_cluster_with_plan(2, plan.clone());
    let w0 = {
        let mut rng = Pcg32::new(61);
        quad_src(0, d).init_params(&mut rng)
    };
    // Twins recompute each worker's expected round-0 payload offline.
    let expected: Vec<Vec<f32>> = (0..2)
        .map(|m| {
            let mut twin = DqganWorker::new(w0.clone(), lr.clone(), Arc::new(Identity));
            let mut src = quad_src(m, d);
            let mut rng = Pcg32::new(900 + m as u64);
            twin.produce(&mut src, batch, &mut rng).unwrap().dense.to_vec()
        })
        .collect();
    let mut workers: Vec<DqganWorker> = (0..2)
        .map(|_| DqganWorker::new(w0.clone(), lr.clone(), Arc::new(Identity)))
        .collect();
    let (recs, summaries) = std::thread::scope(|s| {
        let handles: Vec<_> = worker_ends
            .into_iter()
            .zip(workers.iter_mut())
            .enumerate()
            .map(|(m, (mut end, wk))| {
                s.spawn(move || {
                    let mut src = quad_src(m, d);
                    let mut rng = Pcg32::new(900 + m as u64);
                    worker_loop(&mut end, wk, &mut src, batch, 1, &mut rng, false, None)
                        .unwrap()
                })
            })
            .collect();
        let plan = plan.clone();
        let cfg = AggregatorConfig::streaming_with_policy(PolicyConfig::KofM { k: 1 });
        let recs = serve_rounds_with(&mut server, identity_decoder(), d, 1, cfg, |rec| {
            // Structural proof the round closed without the straggler:
            // its gate is still held when the record is produced.
            assert!(plan.is_held(1, rec.round));
            assert_eq!(rec.workers_included, 1);
            assert_eq!(rec.workers_skipped, 1);
            plan.release(1, rec.round);
        })
        .unwrap();
        let summaries: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        (recs, summaries)
    });
    assert_eq!(recs.len(), 1);
    // The broadcast was worker 0's payload alone; both workers applied
    // it, so parameters stay in lockstep at w0 − q̂⁽⁰⁾ bit-for-bit.
    assert_eq!(summaries[0].rounds, 1);
    assert_eq!(summaries[1].rounds, 1);
    assert_eq!(summaries[0].final_params, summaries[1].final_params);
    for i in 0..d {
        let want = w0[i] - expected[0][i];
        assert_eq!(summaries[0].final_params[i].to_bits(), want.to_bits(), "element {i}");
    }
    // Skipped worker: e grew from 0 to exactly its sent payload.
    for i in 0..d {
        assert_eq!(
            workers[1].error()[i].to_bits(),
            expected[1][i].to_bits(),
            "skipped worker error-memory element {i}"
        );
    }
    assert!(
        dqgan::util::stats::norm2_sq(workers[1].error()) > 0.0,
        "skipped payload must be non-trivial"
    );
    // Included worker keeps an empty error memory under Identity.
    assert!(workers[0].error().iter().all(|&x| x == 0.0));
}

#[test]
fn worker_left_rounds_behind_at_teardown_drains_trailing_broadcasts_cleanly() {
    // Regression (teardown race): worker 1's round-0 send stays gated
    // while kofm:1 closes BOTH rounds on worker 0 and the leader shuts
    // down. Released after the server is gone, worker 1's send fails and
    // it must drain the queued trailing broadcasts — applying each in
    // order (staying in lockstep), re-absorbing only round 0 (the one
    // payload it actually produced) — and exit cleanly on Shutdown.
    let d = 8usize;
    let batch = 4usize;
    let lr = LrSchedule::constant(0.1);
    let plan = DelayPlan::new();
    plan.hold(1, 0);
    let (server, worker_ends, _) = inproc_cluster_with_plan(2, plan.clone());
    let mut server = server;
    let w0 = {
        let mut rng = Pcg32::new(71);
        quad_src(0, d).init_params(&mut rng)
    };
    let expected_q1 = {
        let mut twin = DqganWorker::new(w0.clone(), lr.clone(), Arc::new(Identity));
        let mut src = quad_src(1, d);
        let mut rng = Pcg32::new(700 + 1);
        twin.produce(&mut src, batch, &mut rng).unwrap().dense.to_vec()
    };
    let mut workers: Vec<DqganWorker> = (0..2)
        .map(|_| DqganWorker::new(w0.clone(), lr.clone(), Arc::new(Identity)))
        .collect();
    let summaries = std::thread::scope(|s| {
        let handles: Vec<_> = worker_ends
            .into_iter()
            .zip(workers.iter_mut())
            .enumerate()
            .map(|(m, (mut end, wk))| {
                s.spawn(move || {
                    let mut src = quad_src(m, d);
                    let mut rng = Pcg32::new(700 + m as u64);
                    worker_loop(&mut end, wk, &mut src, batch, 2, &mut rng, false, None)
                        .unwrap()
                })
            })
            .collect();
        let cfg = AggregatorConfig::streaming_with_policy(PolicyConfig::KofM { k: 1 });
        let recs = serve_rounds_with(&mut server, identity_decoder(), d, 2, cfg, |_| {}).unwrap();
        // Both rounds closed on worker 0 alone; worker 1 never arrived.
        assert_eq!((recs[0].workers_included, recs[0].workers_skipped), (1, 1));
        assert_eq!((recs[1].workers_included, recs[1].workers_skipped), (1, 1));
        // Tear the transport down BEFORE releasing the gate, so worker
        // 1's send deterministically fails and exercises the drain path.
        drop(server);
        plan.release_all();
        let summaries: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        summaries
    });
    // Worker 1 applied both trailing broadcasts: full round count, and
    // parameters in lockstep with the survivor.
    assert_eq!(summaries[0].rounds, 2);
    assert_eq!(summaries[1].rounds, 2);
    assert_eq!(summaries[0].final_params, summaries[1].final_params);
    // Exactly one re-absorption (round 0's payload, once — not doubled
    // by the round-1 broadcast it never produced a payload for).
    for i in 0..d {
        assert_eq!(
            workers[1].error()[i].to_bits(),
            expected_q1[i].to_bits(),
            "skipped worker error-memory element {i}"
        );
    }
}

#[test]
fn deadline_rounds_close_after_grace_and_drain_late_frames_inproc() {
    let (m, d) = (3usize, 4usize);
    let plan = DelayPlan::new();
    // Worker 2's round-0 frame is the scripted straggler; the prompt
    // workers' round-1 frames are additionally gated behind worker 2's
    // catch-up, so the late round-0 frame provably sits in the channel
    // before any round-1 frame — the drain ordering is happens-before,
    // not a wall-clock race.
    plan.hold(2, 0);
    plan.hold(0, 1);
    plan.hold(1, 1);
    let (mut server, worker_ends, _) = inproc_cluster_with_plan(m, plan.clone());
    let handles: Vec<_> = worker_ends
        .into_iter()
        .map(|mut w| {
            let plan = plan.clone();
            std::thread::spawn(move || {
                let id = w.id();
                let mut broadcasts = Vec::new();
                for round in 0..2u64 {
                    let mut wire = Vec::new();
                    Identity.encode(&[(id + 1) as f32; 4], &mut wire);
                    w.send(Message::payload(id, round, wire)).unwrap();
                    if id == 2 && round == 1 {
                        // Our late round-0 frame and this round-1 frame
                        // are now queued: let the prompt workers send.
                        plan.release(0, 1);
                        plan.release(1, 1);
                    }
                    let b = w.recv().unwrap();
                    assert_eq!(b.round, round);
                    broadcasts.push(b);
                }
                assert_eq!(w.recv().unwrap().kind, MsgKind::Shutdown);
                broadcasts
            })
        })
        .collect();
    let cfg = AggregatorConfig::streaming_with_policy(PolicyConfig::Deadline {
        grace_ms: 1000,
        arm_at: 2,
    });
    let plan2 = plan.clone();
    let recs = serve_rounds_with(&mut server, identity_decoder(), d, 2, cfg, |rec| {
        if rec.round == 0 {
            // The grace window elapsed with worker 2's gate still held.
            assert!(plan2.is_held(2, 0));
            plan2.release(2, 0);
        }
    })
    .unwrap();
    // Round 0 closed by deadline expiry on workers {0, 1}; the leader
    // provably blocked through the grace window.
    assert_eq!((recs[0].workers_included, recs[0].workers_skipped), (2, 1));
    assert!(recs[0].wait_secs >= 0.1, "grace window not waited: {}", recs[0].wait_secs);
    // Round 1: worker 2's late round-0 frame drains, then all three land.
    assert_eq!((recs[1].workers_included, recs[1].workers_skipped), (3, 0));
    let per_worker: Vec<Vec<Message>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    for bs in &per_worker {
        // Round 0: mean of workers {0, 1} = (1 + 2)/2; round 1: all three.
        assert_eq!(bs[0].kind, MsgKind::PartialBroadcast);
        let mut r = Reader::new(&bs[0].payload);
        let bitmap = read_inclusion_bitmap(&mut r).unwrap();
        assert!(dqgan::comm::bitmap_included(bitmap, 0));
        assert!(dqgan::comm::bitmap_included(bitmap, 1));
        assert!(!dqgan::comm::bitmap_included(bitmap, 2));
        assert_eq!(r.f32_vec(d).unwrap(), vec![1.5; 4]);
        // Round 1 closed with everyone included, so the frame reverts to
        // the plain Broadcast — "all included ⇒ full-barrier bytes" is
        // structural.
        assert_eq!(bs[1].kind, MsgKind::Broadcast);
        assert_eq!(Identity.decode(&bs[1].payload, d).unwrap(), vec![2.0; 4]);
    }
}

#[test]
fn deadline_rounds_drain_late_frames_over_tcp() {
    // Same scripted scenario as the inproc test, but over real sockets:
    // worker 2's round-0 send is channel-gated, the deadline closes the
    // round on {0, 1}, and the late frame drains at round 1's start.
    let (m, d) = (3usize, 4usize);
    let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
    let addr = builder.addr();
    let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
    // The prompt workers' round-1 sends wait for worker 2's catch-up, so
    // its late round-0 + round-1 frames are on the wire before theirs.
    // Unlike the inproc twin this is not a full happens-before proof —
    // the per-socket reader threads race into the arrival channel — so
    // the grace window below is kept generous (1 s) as the backstop.
    let (g0_tx, g0_rx) = std::sync::mpsc::channel::<()>();
    let (g1_tx, g1_rx) = std::sync::mpsc::channel::<()>();
    let mut handles = Vec::new();
    for (id, g_rx) in [(0u32, g0_rx), (1u32, g1_rx)] {
        handles.push(std::thread::spawn(move || {
            let mut w = TcpWorkerEnd::connect(&addr.to_string(), id).unwrap();
            for round in 0..2u64 {
                if round == 1 {
                    g_rx.recv().unwrap(); // until worker 2 has caught up
                }
                let mut wire = Vec::new();
                Identity.encode(&[(id + 1) as f32; 4], &mut wire);
                w.send(Message::payload(id, round, wire)).unwrap();
                let b = w.recv().unwrap();
                assert_eq!(b.round, round);
            }
            assert_eq!(w.recv().unwrap().kind, MsgKind::Shutdown);
        }));
    }
    handles.push(std::thread::spawn(move || {
        let mut w = TcpWorkerEnd::connect(&addr.to_string(), 2).unwrap();
        for round in 0..2u64 {
            if round == 0 {
                gate_rx.recv().unwrap(); // held until round 0 has closed
            }
            let mut wire = Vec::new();
            Identity.encode(&[3.0f32; 4], &mut wire);
            w.send(Message::payload(2, round, wire)).unwrap();
            if round == 1 {
                // Late round-0 frame and round-1 frame are on the wire:
                // release the prompt workers' round-1 sends.
                g0_tx.send(()).unwrap();
                g1_tx.send(()).unwrap();
            }
            let b = w.recv().unwrap();
            assert_eq!(b.round, round);
        }
        assert_eq!(w.recv().unwrap().kind, MsgKind::Shutdown);
    }));
    let mut server = builder.accept(m).unwrap();
    let cfg = AggregatorConfig::streaming_with_policy(PolicyConfig::Deadline {
        grace_ms: 1000,
        arm_at: 2,
    });
    let recs = serve_rounds_with(&mut server, identity_decoder(), d, 2, cfg, |rec| {
        if rec.round == 0 {
            gate_tx.send(()).unwrap();
        }
    })
    .unwrap();
    assert_eq!((recs[0].workers_included, recs[0].workers_skipped), (2, 1));
    assert!(recs[0].wait_secs >= 0.1, "grace window not waited: {}", recs[0].wait_secs);
    assert_eq!((recs[1].workers_included, recs[1].workers_skipped), (3, 0));
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn worker_summary_reports_rounds_actually_completed_on_early_shutdown() {
    // Regression: the summary used to echo the requested round count
    // even when the server shut the run down early.
    let d = 6usize;
    let (server, worker_ends, _) = inproc_cluster(1);
    let mut server = server;
    let mut algo = {
        let mut rng = Pcg32::new(3);
        let w0 = quad_src(0, d).init_params(&mut rng);
        DqganWorker::new(w0, LrSchedule::constant(0.05), Arc::new(Identity))
    };
    let summary = std::thread::scope(|s| {
        let mut end = worker_ends.into_iter().next().unwrap();
        let algo = &mut algo;
        let h = s.spawn(move || {
            let mut src = quad_src(0, d);
            let mut rng = Pcg32::new(5);
            // The worker asks for 10 rounds; the server serves 3.
            worker_loop(&mut end, algo, &mut src, 4, 10, &mut rng, false, None).unwrap()
        });
        serve_rounds(&mut server, identity_decoder(), d, 3, |_| {}).unwrap();
        drop(server); // unblocks the worker's trailing recv
        h.join().unwrap()
    });
    assert_eq!(summary.rounds, 3, "must report completed rounds, not the requested count");
}

#[test]
fn kofm_cluster_trains_end_to_end_with_rotating_skips() {
    // Full distributed run under kofm:2 of M=3: every round closes the
    // moment the 2nd payload is accepted, so exactly one worker is
    // skipped per round (whoever arrives last) — and error feedback
    // still carries the run to the optimum.
    let cfg = ClusterConfig {
        algo: AlgoKind::parse("dqgan:linf8").unwrap(),
        workers: 3,
        batch: 8,
        rounds: 1200,
        lr: LrSchedule::constant(0.1),
        seed: 11,
        eval_every: 0,
        keep_stats: false,
        agg: AggregatorConfig::streaming_with_policy(PolicyConfig::KofM { k: 2 }),
        transport: Default::default(),
        chaos_kill: None,
    };
    let report = run_cluster(&cfg, |_m| {
        let mut rng = Pcg32::new(321);
        Ok(Box::new(QuadraticOperator::new(12, 0.1, &mut rng)))
    })
    .unwrap();
    for r in &report.records {
        assert_eq!(
            (r.workers_included, r.workers_skipped),
            (2, 1),
            "kofm:2 closes at exactly the quorum (round {})",
            r.round
        );
    }
    let target = {
        let mut rng = Pcg32::new(321);
        QuadraticOperator::new(12, 0.1, &mut rng).target
    };
    let dist = dqgan::util::stats::dist2_sq(&report.worker0.final_params, &target).sqrt();
    assert!(dist < 0.5, "kofm run must still converge: dist {dist}");
}
