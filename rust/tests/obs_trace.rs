//! Integration: the observability subsystem (ADR-004) end to end — one
//! seeded in-process pipelined run with every obs sink live, validating
//!
//! 1. the **bitwise contract**: per-round broadcast checksums and final
//!    parameters identical to an obs-disabled run of the same seed
//!    (obs records counts and clock durations only, never numerics);
//! 2. the `--metrics-json` dump: schema-valid, every declared metric
//!    present, hot-path counters and histograms actually populated;
//! 3. the `--trace` file: parseable Chrome trace-event JSON with the
//!    documented lane convention (leader tid 0, worker i tid 1+i) and
//!    decode spans nesting inside their round's gather span;
//! 4. the `--worker-csv` rows: one per (worker, round) with apply
//!    latency and ack RTT populated on the ack-based transport;
//! 5. the round-record columns the obs PR added: `bytes_down` present
//!    under a counter-exposing transport, `threads_peak` optional.
//!
//! Runs in its own test binary on purpose: the obs enables are sticky
//! process-globals, so the baseline (disabled) run must come first —
//! this file keeps a single #[test] to own that ordering.

use dqgan::algo::AlgoKind;
use dqgan::config::{AggregatorConfig, TransportMode};
use dqgan::grad::QuadraticOperator;
use dqgan::obs;
use dqgan::optim::LrSchedule;
use dqgan::ps::{run_cluster, ClusterConfig, TrainReport};
use dqgan::util::json::Json;
use dqgan::util::rng::Pcg32;

const WORKERS: usize = 3;
const ROUNDS: u64 = 4;
const DIM: usize = 16;

fn cfg() -> ClusterConfig {
    ClusterConfig {
        algo: AlgoKind::parse("dqgan:linf8").unwrap(),
        workers: WORKERS,
        batch: 8,
        rounds: ROUNDS,
        lr: LrSchedule::constant(0.05),
        seed: 4242,
        eval_every: 0,
        keep_stats: false,
        agg: AggregatorConfig::pipelined(),
        transport: TransportMode::EvLoop,
        chaos_kill: None,
    }
}

fn run() -> TrainReport {
    run_cluster(&cfg(), |_m| {
        let mut rng = Pcg32::new(777);
        Ok(Box::new(QuadraticOperator::new(DIM, 0.1, &mut rng)))
    })
    .unwrap()
}

fn fnvs(r: &TrainReport) -> Vec<(u64, u64)> {
    r.records.iter().map(|x| (x.round, x.broadcast_fnv)).collect()
}

#[test]
fn observability_sinks_are_complete_and_bitwise_invisible() {
    // ---- Baseline: obs fully disabled (must run before any enable —
    // the flags are sticky for the process lifetime).
    assert!(!obs::metrics_enabled() && !obs::trace_enabled(), "obs off at binary start");
    let baseline = run();

    obs::enable_worker_rows(); // implies enable_metrics
    obs::enable_trace();
    let observed = run();

    // ---- 1. Bitwise contract: same checksums, same final parameters.
    assert_eq!(fnvs(&baseline), fnvs(&observed), "obs flags must not move a broadcast bit");
    assert_eq!(baseline.worker0.final_params, observed.worker0.final_params);

    // ---- 5. New round-record columns.
    for r in &observed.records {
        assert!(r.bytes_down.is_some(), "evloop transport exposes a byte counter");
    }
    let total_down: u64 = observed.records.iter().filter_map(|r| r.bytes_down).sum();
    assert!(total_down > 0, "pipelined run broadcast real downlink bytes");
    #[cfg(target_os = "linux")]
    assert!(observed.records[0].threads_peak.is_some(), "procfs thread census on Linux");

    let dir = std::env::temp_dir().join(format!("dqgan_obs_trace_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    // ---- 2. Metrics dump: schema-valid, complete, populated.
    let metrics_path = dir.join("metrics.json");
    let mut meta = std::collections::BTreeMap::new();
    meta.insert("workers".to_string(), Json::Num(WORKERS as f64));
    obs::write_metrics_json(&metrics_path, meta).unwrap();
    let doc = Json::parse(&std::fs::read_to_string(&metrics_path).unwrap()).unwrap();
    obs::check_metrics_json(&doc).unwrap();
    assert_eq!(doc.get("schema").unwrap().as_str(), Some(obs::SCHEMA));
    let counter = |name: &str| doc.get("counters").unwrap().get(name).unwrap().as_f64().unwrap();
    assert!(counter("evloop.deliveries") > 0.0, "evloop delivered broadcast frames");
    assert!(counter("transport.bytes_down") > 0.0, "run-end transport totals folded in");
    assert!(counter("codec.bytes_pre_total") >= counter("codec.bytes_post_total"));
    let hist_count = |name: &str| {
        doc.get("histograms").unwrap().get(name).unwrap().get("count").unwrap().as_f64().unwrap()
    };
    assert!(hist_count("codec.encode_ns") > 0.0, "worker encodes were timed");
    assert!(hist_count("codec.decode_ns") > 0.0, "leader decodes were timed");
    assert!(hist_count("worker.apply_ns") > 0.0, "worker applies were timed");

    // ---- 4. Worker CSV: header + one row per (worker, round).
    let csv_path = dir.join("workers.csv");
    obs::write_worker_csv(&csv_path).unwrap();
    let text = std::fs::read_to_string(&csv_path).unwrap();
    let mut lines = text.lines();
    assert_eq!(lines.next().unwrap(), obs::WORKER_CSV_HEADER.join(","));
    let rows: Vec<Vec<&str>> = lines.map(|l| l.split(',').collect()).collect();
    assert!(
        rows.len() >= WORKERS * ROUNDS as usize,
        "one row per (worker, round): got {}",
        rows.len()
    );
    assert!(rows.iter().all(|r| !r[2].is_empty()), "apply_ns populated everywhere");
    assert!(rows.iter().any(|r| !r[3].is_empty()), "ack RTT populated on the ack transport");
    assert!(rows.iter().all(|r| r[4] == "0"), "full-barrier run absorbs no skips");
    assert!(rows.iter().any(|r| !r[5].is_empty()), "error-memory norm populated");

    // ---- 3. Trace file: valid trace-event JSON, lane + nesting
    // invariants.
    let trace_path = dir.join("trace.json");
    obs::write_trace(&trace_path).unwrap();
    let tdoc = Json::parse(&std::fs::read_to_string(&trace_path).unwrap()).unwrap();
    let events = tdoc.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    let leader_names = ["gather", "decode", "reduce", "close", "broadcast"];
    let worker_names = ["produce", "recv", "apply", "ack"];
    let field = |e: &Json, k: &str| e.get(k).unwrap().as_f64().unwrap();
    for e in events {
        let name = e.get("name").unwrap().as_str().unwrap();
        assert_eq!(e.get("ph").unwrap().as_str(), Some("X"), "complete events only");
        assert_eq!(field(e, "pid"), 1.0);
        assert!(field(e, "ts") >= 0.0 && field(e, "dur") >= 0.0);
        let tid = field(e, "tid");
        let round = e.get("args").unwrap().get("round").unwrap().as_f64().unwrap();
        assert!(round < ROUNDS as f64, "span rounds stay in range: {name} @ {round}");
        if leader_names.contains(&name) {
            assert_eq!(tid, 0.0, "leader span {name} on the leader lane");
        } else {
            assert!(worker_names.contains(&name), "unknown span name {name}");
            assert!(
                (1.0..=WORKERS as f64).contains(&tid),
                "worker span {name} on a worker lane, got tid {tid}"
            );
        }
    }
    for want in leader_names.iter().chain(&worker_names) {
        assert!(
            events.iter().any(|e| e.get("name").unwrap().as_str() == Some(*want)),
            "span {want} missing from trace"
        );
    }
    // Every leader decode span nests inside its round's gather span.
    let eps = 1.0; // µs of f64 slack
    for d in events.iter().filter(|e| e.get("name").unwrap().as_str() == Some("decode")) {
        let round = d.get("args").unwrap().get("round").unwrap().as_f64().unwrap();
        let g = events
            .iter()
            .find(|e| {
                e.get("name").unwrap().as_str() == Some("gather")
                    && e.get("args").unwrap().get("round").unwrap().as_f64() == Some(round)
            })
            .expect("gather span for the decode's round");
        let (dts, dend) = (field(d, "ts"), field(d, "ts") + field(d, "dur"));
        let (gts, gend) = (field(g, "ts"), field(g, "ts") + field(g, "dur"));
        assert!(
            dts >= gts - eps && dend <= gend + eps,
            "decode [{dts}, {dend}] outside gather [{gts}, {gend}] in round {round}"
        );
    }

    std::fs::remove_dir_all(&dir).ok();
}
