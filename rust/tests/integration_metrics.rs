//! Integration: the metric stack discriminates real quality differences —
//! the property Figures 2–3 rely on.

use dqgan::data::{SynthImages, IMG_LEN};
use dqgan::metrics::{
    fid_from_features, inception_score, FeatureNet, FEATURE_DIM, NUM_CLASSES,
};
use dqgan::util::rng::Pcg32;

fn batch(ds: &SynthImages, n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    ds.sample_batch(n, &mut rng).0
}

#[test]
fn fid_of_real_vs_real_is_small_and_real_vs_noise_is_large() {
    let ds = SynthImages::cifar_like(1);
    let net = FeatureNet::new();
    let n = 128;
    let (fa, _) = net.features_batch(&batch(&ds, n, 2));
    let (fb, _) = net.features_batch(&batch(&ds, n, 3));
    let fid_rr = fid_from_features(&fa, n, &fb, n, FEATURE_DIM).fid;

    // "Generator collapse" stand-in: pure noise images.
    let mut rng = Pcg32::new(4);
    let noise: Vec<f32> = (0..n * IMG_LEN).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
    let (fn_, _) = net.features_batch(&noise);
    let fid_rn = fid_from_features(&fa, n, &fn_, n, FEATURE_DIM).fid;
    assert!(
        fid_rn > 5.0 * fid_rr.max(1e-3),
        "FID must separate real ({fid_rr:.3}) from noise ({fid_rn:.3})"
    );
}

#[test]
fn fid_decreases_as_distributions_match_better() {
    // Mix k% noise into the "generated" batch: FID must rise with k.
    let ds = SynthImages::cifar_like(5);
    let net = FeatureNet::new();
    let n = 96;
    let real = batch(&ds, n, 6);
    let (freal, _) = net.features_batch(&real);
    let mut rng = Pcg32::new(7);
    let mut prev_fid = -1.0f32;
    for frac_noisy in [0usize, 3, 8] {
        let mut gen = batch(&ds, n, 8);
        for i in 0..(n * frac_noisy / 10) {
            for p in gen[i * IMG_LEN..(i + 1) * IMG_LEN].iter_mut() {
                *p = rng.uniform_range(-1.0, 1.0);
            }
        }
        let (fgen, _) = net.features_batch(&gen);
        let fid = fid_from_features(&freal, n, &fgen, n, FEATURE_DIM).fid;
        assert!(
            fid > prev_fid,
            "FID must grow with corruption: {prev_fid} → {fid} at {frac_noisy}/10 noisy"
        );
        prev_fid = fid;
    }
}

#[test]
fn inception_proxy_rewards_class_diversity_of_real_data() {
    let ds = SynthImages::cifar_like(9);
    let net = FeatureNet::new();
    let n = 160;
    // Diverse real batch (all classes).
    let (_, logits_div) = net.features_batch(&batch(&ds, n, 10));
    let is_diverse = inception_score(&logits_div, n);
    // Collapsed batch: a single class rendered n times.
    let mut rng = Pcg32::new(11);
    let mut collapsed = vec![0.0f32; n * IMG_LEN];
    for i in 0..n {
        ds.render(3, &mut rng, &mut collapsed[i * IMG_LEN..(i + 1) * IMG_LEN]);
    }
    let (_, logits_col) = net.features_batch(&collapsed);
    let is_collapsed = inception_score(&logits_col, n);
    assert!(
        is_diverse > is_collapsed,
        "IS must reward diversity: diverse={is_diverse:.3} collapsed={is_collapsed:.3}"
    );
    assert!(is_diverse <= NUM_CLASSES as f32 + 1e-3);
    assert!(is_collapsed >= 1.0 - 1e-3);
}

#[test]
fn both_synthetic_datasets_have_usable_class_structure() {
    // The feature embedding separates classes on both datasets (needed for
    // fig2 vs fig3 to be distinct experiments).
    for ds in [SynthImages::cifar_like(12), SynthImages::faces_like(12)] {
        let net = FeatureNet::new();
        let mut rng = Pcg32::new(13);
        let per_class = 12;
        let mut feats: Vec<Vec<f32>> = Vec::new();
        let mut buf = vec![0.0f32; IMG_LEN];
        for cls in 0..3 {
            let mut acc = vec![0.0f32; FEATURE_DIM];
            for _ in 0..per_class {
                ds.render(cls, &mut rng, &mut buf);
                let (f, _) = net.features(&buf);
                for (a, b) in acc.iter_mut().zip(&f) {
                    *a += b / per_class as f32;
                }
            }
            feats.push(acc);
        }
        // Class centroids must be pairwise separated.
        for i in 0..3 {
            for j in i + 1..3 {
                let d = dqgan::util::stats::dist2_sq(&feats[i], &feats[j]);
                assert!(d > 1e-4, "classes {i},{j} indistinguishable (d={d})");
            }
        }
    }
}
