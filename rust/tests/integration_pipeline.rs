//! Integration: the pipelined round engine — async writer-thread
//! broadcast plus double-buffered aggregation — locked down by a
//! cross-transport equivalence suite: every scheduling change must be
//! **bitwise-invisible** in the broadcast frames. Stragglers and slow
//! receivers are scripted with [`DelayPlan`] gates (uplink and
//! downlink), never sleeps.

use dqgan::algo::AlgoKind;
use dqgan::comm::tcp::{TcpServerBuilder, TcpWorkerEnd};
use dqgan::comm::{
    inproc_cluster_with_plan, DelayPlan, Message, MsgKind, ServerEnd, StreamDirective,
    StreamOutcome, WorkerEnd,
};
use dqgan::compress::{compressor_from_spec, Compressor, Identity};
use dqgan::config::{AggMode, AggregatorConfig, PolicyConfig};
use dqgan::grad::QuadraticOperator;
use dqgan::optim::LrSchedule;
use dqgan::ps::{
    run_cluster, serve_rounds_with, worker_loop, Aggregator, ClusterConfig, Decoder,
};
use dqgan::util::bytes::put_f32_slice;
use dqgan::util::rng::Pcg32;
use std::sync::Arc;

const ROUNDS: u64 = 3;

fn identity_decoder() -> Decoder {
    Arc::new(|bytes: &[u8], out: &mut [f32]| Identity.decode_into(bytes, out))
}

/// Precompute every worker's wire payload per round (`wires[w][r]`), so
/// streaming and pipelined runs see byte-identical payload streams.
fn round_payloads(spec: &str, m: usize, d: usize, seed: u64) -> Vec<Vec<Vec<u8>>> {
    let c = compressor_from_spec(spec).unwrap();
    let mut rng = Pcg32::new(seed);
    (0..m)
        .map(|_| {
            (0..ROUNDS)
                .map(|_| {
                    let v = rng.normal_vec(d);
                    let mut wire = Vec::new();
                    c.compress_encoded(&v, &mut rng, &mut wire);
                    wire
                })
                .collect()
        })
        .collect()
}

fn spec_decoder(spec: &str) -> Decoder {
    let c = compressor_from_spec(spec).unwrap();
    Arc::new(move |bytes: &[u8], out: &mut [f32]| c.decode_into(bytes, out))
}

/// Drive one scripted worker: send the prebuilt payload each round,
/// collect every downlink frame verbatim (the bytes under comparison).
fn drive_worker(w: &mut dyn WorkerEnd, wires: &[Vec<u8>]) -> Vec<Message> {
    let id = w.id();
    let mut frames = Vec::new();
    for (r, wire) in wires.iter().enumerate() {
        w.send(Message::payload(id, r as u64, wire.clone())).unwrap();
        let b = w.recv().unwrap();
        assert_eq!(b.round, r as u64);
        frames.push(b);
    }
    assert_eq!(w.recv().unwrap().kind, MsgKind::Shutdown);
    frames
}

/// Hold every (worker, round) uplink gate, then release them round by
/// round in a seed-scrambled worker order from a separate thread — the
/// frames reach the leader in an order the seed controls, not worker-id
/// order. (The property under test is exactly that no arrival order can
/// change a broadcast bit.)
fn scrambled_releaser(
    plan: &DelayPlan,
    m: usize,
    seed: u64,
) -> std::thread::JoinHandle<()> {
    for w in 0..m as u32 {
        for r in 0..ROUNDS {
            plan.hold(w, r);
        }
    }
    let plan = plan.clone();
    std::thread::spawn(move || {
        let mut rng = Pcg32::new(seed);
        for r in 0..ROUNDS {
            let mut order: Vec<u32> = (0..m as u32).collect();
            rng.shuffle(&mut order);
            for w in order {
                plan.release(w, r);
            }
        }
    })
}

/// One full run over the in-process transport; returns each worker's
/// received downlink frames.
fn run_inproc(
    cfg: AggregatorConfig,
    d: usize,
    wires: &[Vec<Vec<u8>>],
    decoder: Decoder,
    scramble_seed: u64,
) -> Vec<Vec<Message>> {
    let m = wires.len();
    let plan = DelayPlan::new();
    let releaser = scrambled_releaser(&plan, m, scramble_seed);
    let (mut server, worker_ends, _) = inproc_cluster_with_plan(m, plan);
    let frames = std::thread::scope(|s| {
        let handles: Vec<_> = worker_ends
            .into_iter()
            .zip(wires)
            .map(|(mut end, ws)| s.spawn(move || drive_worker(&mut end, ws)))
            .collect();
        serve_rounds_with(&mut server, decoder, d, ROUNDS, cfg, |_| {}).unwrap();
        handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>()
    });
    releaser.join().unwrap();
    frames
}

/// One full run over real TCP sockets; same contract as [`run_inproc`].
fn run_tcp(
    cfg: AggregatorConfig,
    d: usize,
    wires: &[Vec<Vec<u8>>],
    decoder: Decoder,
    scramble_seed: u64,
) -> Vec<Vec<Message>> {
    let m = wires.len();
    let plan = DelayPlan::new();
    let releaser = scrambled_releaser(&plan, m, scramble_seed);
    let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
    let addr = builder.addr();
    let handles: Vec<_> = wires
        .iter()
        .cloned()
        .enumerate()
        .map(|(i, ws)| {
            let plan = plan.clone();
            std::thread::spawn(move || {
                let mut end =
                    TcpWorkerEnd::connect_with_plan(&addr.to_string(), i as u32, Some(plan))
                        .unwrap();
                drive_worker(&mut end, &ws)
            })
        })
        .collect();
    let mut server = builder.accept(m).unwrap();
    serve_rounds_with(&mut server, decoder, d, ROUNDS, cfg, |_| {}).unwrap();
    let frames: Vec<Vec<Message>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    releaser.join().unwrap();
    frames
}

#[test]
fn pipelined_broadcasts_are_bitwise_identical_to_streaming_inproc() {
    // The cross-transport equivalence property, in-process half: over
    // qsgd/sign/topk payloads, M ∈ {1, 4, 8} and pipeline depth ∈
    // {1, 2}, with scrambled DelayPlan arrival orders, every worker's
    // downlink frame stream (kind, round and payload bytes) under
    // `--agg pipelined` equals the `--agg streaming` reference exactly.
    let d = 1031;
    for (si, spec) in ["qsgd8", "sign", "topk(f=0.1)"].into_iter().enumerate() {
        for &m in &[1usize, 4, 8] {
            let wires = round_payloads(spec, m, d, 0x51EE7 + si as u64 * 131 + m as u64);
            let reference = run_inproc(
                AggregatorConfig::streaming(),
                d,
                &wires,
                spec_decoder(spec),
                1,
            );
            for depth in [1usize, 2] {
                let got = run_inproc(
                    AggregatorConfig::pipelined_with_depth(depth),
                    d,
                    &wires,
                    spec_decoder(spec),
                    100 + depth as u64,
                );
                assert_eq!(got, reference, "{spec} M={m} depth={depth} (inproc)");
            }
        }
    }
}

#[test]
fn pipelined_broadcasts_are_bitwise_identical_to_streaming_tcp() {
    // TCP half of the equivalence suite: the same property through real
    // sockets, reader threads and writer threads (socket races provide
    // extra arrival scrambling on top of the gate schedule).
    let d = 1031;
    for (si, spec) in ["qsgd8", "sign", "topk(f=0.1)"].into_iter().enumerate() {
        for &m in &[1usize, 4] {
            let wires = round_payloads(spec, m, d, 0x7CB + si as u64 * 17 + m as u64);
            let reference =
                run_tcp(AggregatorConfig::streaming(), d, &wires, spec_decoder(spec), 3);
            for depth in [1usize, 2] {
                let got = run_tcp(
                    AggregatorConfig::pipelined_with_depth(depth),
                    d,
                    &wires,
                    spec_decoder(spec),
                    300 + depth as u64,
                );
                assert_eq!(got, reference, "{spec} M={m} depth={depth} (tcp)");
            }
        }
    }
}

#[test]
fn round_t_plus_1_frames_decode_while_round_t_broadcast_is_gate_held() {
    // Deterministic overlap probe (no sleeps, PR-3 DelayPlan pattern):
    // worker 2's round-0 broadcast delivery is downlink-gated, the two
    // prompt workers advance to round 1, and the leader observes round-1
    // slot occupancy in the aggregator's second bank while the round-0
    // broadcast handle is provably not done and the gate provably held.
    let (m, d) = (3usize, 64usize);
    let plan = DelayPlan::new();
    plan.hold_down(2, 0);
    let (mut server, worker_ends, _) = inproc_cluster_with_plan(m, plan.clone());
    server.set_pipeline_depth(2);
    let decoder = identity_decoder();
    let handles: Vec<_> = worker_ends
        .into_iter()
        .map(|mut w| {
            std::thread::spawn(move || {
                let id = w.id();
                for round in 0..2u64 {
                    let v = vec![(id + 1) as f32; 64];
                    let mut wire = Vec::new();
                    Identity.encode(&v, &mut wire);
                    w.send(Message::payload(id, round, wire)).unwrap();
                    let b = w.recv().unwrap();
                    assert_eq!(b.round, round);
                }
                assert_eq!(w.recv().unwrap().kind, MsgKind::Shutdown);
            })
        })
        .collect();
    let mut agg = Aggregator::new(AggregatorConfig::pipelined_with_depth(2), d, m);
    assert_eq!(agg.num_banks(), 2);
    // Round 0: all three arrive (worker 2's uplink is not gated).
    agg.begin_round(0);
    server
        .recv_round_streaming(&mut |msg| agg.accept(&msg, &decoder))
        .unwrap();
    let avg0 = agg.finish_round().unwrap().to_vec();
    assert_eq!(avg0, vec![2.0; 64]);
    let mut payload0 = Vec::with_capacity(4 * d);
    put_f32_slice(&mut payload0, &avg0);
    let h0 = server.broadcast_async(Message::broadcast(0, payload0)).unwrap();
    // Round 1 opens in the second bank while broadcast 0 is in flight.
    agg.begin_round(1);
    let mut seen = 0usize;
    let outcome = server
        .recv_round_streaming_timed(&mut |msg| {
            agg.accept(&msg, &decoder)?;
            seen += 1;
            if seen == 2 {
                // The structural heart of the probe: round-1 frames are
                // decoded (slot occupancy observed) while round 0's
                // broadcast is still gate-held on worker 2's writer.
                assert_eq!(agg.arrived_count(), 2);
                assert_eq!(agg.included(), &[true, true, false]);
                assert_eq!(agg.oldest_open_round(), Some(1));
                assert!(plan.is_held_down(2, 0), "round-0 delivery gate must still be held");
                assert!(!h0.is_done(), "round-0 broadcast must still be in flight");
                plan.release_down(2, 0);
            }
            Ok(if seen == 3 { StreamDirective::Close } else { StreamDirective::Wait })
        })
        .unwrap();
    assert_eq!(outcome, StreamOutcome::Closed);
    h0.wait().unwrap();
    assert!(h0.is_done() && h0.completed_at().is_some());
    let avg1 = agg.finish_round().unwrap().to_vec();
    assert_eq!(avg1, vec![2.0; 64]);
    let mut payload1 = Vec::with_capacity(4 * d);
    put_f32_slice(&mut payload1, &avg1);
    // Synchronous sends route through the writers (order preserved) and
    // wait for delivery — the clean teardown path.
    server.broadcast(Message::broadcast(1, payload1)).unwrap();
    server.broadcast(Message::shutdown(2)).unwrap();
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn pipelined_cluster_is_bitwise_identical_to_sequential() {
    // End-to-end A/B across the full distributed stack (real worker
    // algorithm, error feedback, broadcast application): the pipelined
    // trajectory must reproduce the sequential one bit for bit at both
    // pipeline depths.
    let run = |agg: AggregatorConfig| {
        let cfg = ClusterConfig {
            algo: AlgoKind::parse("dqgan:linf8").unwrap(),
            workers: 4,
            batch: 8,
            rounds: 50,
            lr: LrSchedule::constant(0.05),
            seed: 42,
            eval_every: 0,
            keep_stats: false,
            agg,
            transport: Default::default(),
            chaos_kill: None,
        };
        run_cluster(&cfg, |_m| {
            let mut rng = Pcg32::new(7);
            Ok(Box::new(QuadraticOperator::new(64, 0.1, &mut rng)))
        })
        .unwrap()
    };
    let seq = run(AggregatorConfig::sequential());
    for depth in [1usize, 2] {
        let pipe = run(AggregatorConfig::pipelined_with_depth(depth));
        assert_eq!(
            seq.worker0.final_params, pipe.worker0.final_params,
            "depth {depth} must not change a bit"
        );
        assert_eq!(pipe.records.len(), 50);
        for r in &pipe.records {
            assert!(r.wait_secs >= 0.0 && r.agg_secs >= 0.0);
            assert!(r.overlap_secs >= 0.0);
            assert!(
                r.overlap_secs <= r.wall_secs,
                "overlap {} > wall {}",
                r.overlap_secs,
                r.wall_secs
            );
        }
    }
}

#[test]
fn tcp_pipelined_mode_trains_over_real_sockets() {
    // Same protocol as the streaming TCP test, but the leader runs the
    // pipelined engine: reader threads on the uplink, writer threads on
    // the downlink, for all 20 rounds plus a clean shutdown.
    let m = 2usize;
    let rounds = 20u64;
    let dim = 16usize;
    let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
    let addr = builder.addr();
    let algo = AlgoKind::parse("dqgan:linf8").unwrap();
    let mut seed_rng = Pcg32::new(88);
    let w0 = {
        use dqgan::grad::GradientSource;
        let op = QuadraticOperator::new(dim, 0.1, &mut seed_rng);
        op.init_params(&mut seed_rng)
    };
    let mut worker_handles = Vec::new();
    for id in 0..m as u32 {
        let w0 = w0.clone();
        let algo = algo.clone();
        worker_handles.push(std::thread::spawn(move || {
            let mut end = TcpWorkerEnd::connect(&addr.to_string(), id).unwrap();
            let mut worker = algo.build_worker(w0, LrSchedule::constant(0.05));
            let mut rng = Pcg32::new(100 + id as u64);
            let mut src = {
                let mut r = Pcg32::new(55);
                QuadraticOperator::new(dim, 0.1, &mut r)
            };
            worker_loop(&mut end, worker.as_mut(), &mut src, 4, rounds, &mut rng, false, None)
                .unwrap()
        }));
    }
    let mut server = builder.accept(m).unwrap();
    let records = serve_rounds_with(
        &mut server,
        algo.decoder(),
        dim,
        rounds,
        AggregatorConfig::pipelined_with_depth(2),
        |_| {},
    )
    .unwrap();
    assert_eq!(records.len(), rounds as usize);
    let summaries: Vec<_> = worker_handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(summaries[0].final_params, summaries[1].final_params);
    assert_eq!(summaries[0].rounds, rounds);
    assert!(server.counter().up_total() > 0);
    assert!(server.counter().down_total() > 0);
}

#[test]
fn pipelined_kofm_cluster_converges_with_rotating_skips() {
    // Partial-round interplay: pipelined mode under kofm:2 of M=3 —
    // every round closes at the quorum, partial broadcasts ride the
    // writer threads, skipped workers re-absorb via the inclusion
    // bitmap, and error feedback still carries the run to the optimum.
    let cfg = ClusterConfig {
        algo: AlgoKind::parse("dqgan:linf8").unwrap(),
        workers: 3,
        batch: 8,
        rounds: 800,
        lr: LrSchedule::constant(0.1),
        seed: 11,
        eval_every: 0,
        keep_stats: false,
        agg: AggregatorConfig {
            mode: AggMode::Pipelined,
            policy: PolicyConfig::KofM { k: 2 },
            ..Default::default()
        },
        transport: Default::default(),
        chaos_kill: None,
    };
    let report = run_cluster(&cfg, |_m| {
        let mut rng = Pcg32::new(321);
        Ok(Box::new(QuadraticOperator::new(12, 0.1, &mut rng)))
    })
    .unwrap();
    for r in &report.records {
        assert_eq!((r.workers_included, r.workers_skipped), (2, 1), "round {}", r.round);
    }
    let target = {
        let mut rng = Pcg32::new(321);
        QuadraticOperator::new(12, 0.1, &mut rng).target
    };
    let dist = dqgan::util::stats::dist2_sq(&report.worker0.final_params, &target).sqrt();
    assert!(dist < 0.5, "pipelined kofm run must still converge: dist {dist}");
}

#[test]
fn liveness_tolerates_a_slow_but_alive_worker() {
    // Negative control for the liveness timeout: a worker that is one
    // round late every round (gate released only when the round's record
    // is produced) keeps draining its ledger, so --liveness 1 must let
    // the run complete. A token chain makes the drain order
    // happens-before, not a scheduling race: worker 0 sends its round
    // r+1 payload only after worker 1's late round-r frame is already in
    // the uplink channel, so the FIFO gather provably drains the late
    // frame before the round can close. (The positive case — a dead
    // worker converted into a worker error — is pinned in ps/server.rs
    // unit tests.)
    let rounds = 6u64;
    let d = 4usize;
    let plan = DelayPlan::new();
    for r in 0..rounds {
        plan.hold(1, r);
    }
    let (mut server, worker_ends, _) = inproc_cluster_with_plan(2, plan.clone());
    let (token_tx, token_rx) = std::sync::mpsc::channel::<()>();
    let mut it = worker_ends.into_iter();
    let mut w0 = it.next().unwrap();
    let mut w1 = it.next().unwrap();
    let h0 = std::thread::spawn(move || {
        for round in 0..rounds {
            if round > 0 {
                // Wait for worker 1's late round-(r-1) frame to be
                // queued ahead of ours.
                token_rx.recv().unwrap();
            }
            let mut wire = Vec::new();
            Identity.encode(&[0.0f32; 4], &mut wire);
            w0.send(Message::payload(0, round, wire)).unwrap();
            let b = w0.recv().unwrap();
            assert_eq!(b.round, round);
        }
        assert_eq!(w0.recv().unwrap().kind, MsgKind::Shutdown);
    });
    let h1 = std::thread::spawn(move || {
        for round in 0..rounds {
            let mut wire = Vec::new();
            Identity.encode(&[1.0f32; 4], &mut wire);
            // Blocks on the gate until round `round` has already closed
            // without us (released in on_round below).
            w1.send(Message::payload(1, round, wire)).unwrap();
            let _ = token_tx.send(()); // unblock worker 0's next round
            let b = w1.recv().unwrap();
            assert_eq!(b.round, round);
        }
        assert_eq!(w1.recv().unwrap().kind, MsgKind::Shutdown);
    });
    let cfg = AggregatorConfig {
        mode: AggMode::Pipelined,
        policy: PolicyConfig::KofM { k: 1 },
        liveness_rounds: 1,
        ..Default::default()
    };
    let plan2 = plan.clone();
    let recs = serve_rounds_with(&mut server, identity_decoder(), d, rounds, cfg, |rec| {
        assert_eq!(rec.workers_included, 1, "round {} closes on worker 0 alone", rec.round);
        plan2.release(1, rec.round);
    })
    .unwrap();
    assert_eq!(recs.len(), rounds as usize);
    drop(server);
    h0.join().unwrap();
    h1.join().unwrap();
}
