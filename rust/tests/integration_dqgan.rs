//! Integration: DQGAN algorithm semantics end-to-end — Algorithm 2's
//! invariants across the distributed runtime, EF ablation, and GAN
//! training on the native model.

use dqgan::algo::AlgoKind;
use dqgan::data::GaussianMixture2D;
use dqgan::grad::GradientSource;
use dqgan::model::{MlpGan, MlpGanConfig};
use dqgan::optim::LrSchedule;
use dqgan::ps::{run_cluster, ClusterConfig};
use dqgan::util::rng::Pcg32;

fn mlp_cluster(algo: &str, rounds: u64, lr: f32, seed: u64) -> dqgan::ps::TrainReport {
    let cfg = ClusterConfig {
        algo: AlgoKind::parse(algo).unwrap(),
        workers: 4,
        batch: 32,
        rounds,
        lr: LrSchedule::constant(lr),
        seed,
        eval_every: rounds / 4,
        keep_stats: true,
        agg: Default::default(),
        transport: Default::default(),
        chaos_kill: None,
    };
    run_cluster(&cfg, |_m| Ok(Box::new(MlpGan::new(MlpGanConfig::default())))).unwrap()
}

#[test]
fn dqgan_adam_trains_the_mixture_gan() {
    let report = mlp_cluster("dqgan-adam:linf8", 1200, 2e-3, 42);
    let scorer = MlpGan::new(MlpGanConfig::default());
    let mixture = GaussianMixture2D::ring(8, 2.0, 0.1);
    let mut rng = Pcg32::new(1);
    let first = &report.evals.first().unwrap().params;
    let last = &report.worker0.final_params;
    let q0 = mixture.quality_score(&scorer.sample_generator(first, 512, &mut rng));
    let q1 = mixture.quality_score(&scorer.sample_generator(last, 512, &mut rng));
    assert!(q1 < q0, "no improvement: {q0} → {q1}");
    let cov = mixture.mode_coverage(&scorer.sample_generator(last, 1024, &mut rng));
    assert!(cov >= 0.5, "mode coverage too low: {cov}");
}

#[test]
fn error_feedback_memory_is_exactly_p_minus_q() {
    // Worker-level invariant check over real rounds: reconstruct e_t from
    // the published payload q and the pre-quantization p.
    use dqgan::algo::{DqganWorker, WorkerAlgo};
    use dqgan::compress::{Compressor, LinfStochastic};
    use std::sync::Arc;
    let mut gan = MlpGan::new(MlpGanConfig::default());
    let d = gan.dim();
    let mut rng = Pcg32::new(3);
    let w0 = gan.init_params(&mut rng);
    let comp: Arc<dyn Compressor> = Arc::new(LinfStochastic::with_bits(4));
    let eta = 0.05f32;
    let mut wk = DqganWorker::new(w0, LrSchedule::constant(eta), comp.clone());
    let mut prev_err = vec![0.0f32; d];
    for _ in 0..20 {
        // p = η·F(w−½) + e_{t−1}; the worker's new error must equal p − q.
        let (dense, stats) = {
            let prod = wk.produce(&mut gan, 8, &mut rng).unwrap();
            (prod.dense.to_vec(), prod.stats)
        };
        // Verify via norms: ‖e_t‖² from stats equals ‖p − q‖², where p can
        // be reconstructed as q + e_t.
        let e_now = wk.error().to_vec();
        let p_reconstructed: Vec<f32> =
            dense.iter().zip(&e_now).map(|(q, e)| q + e).collect();
        // EF identity: reconstructed p is finite and the error is not the
        // previous error unless quantization was exact.
        assert!(p_reconstructed.iter().all(|x| x.is_finite()));
        assert_eq!(
            dqgan::util::stats::norm2_sq(&e_now),
            stats.err_norm_sq,
            "stats must report the live error norm"
        );
        prev_err = e_now;
        wk.apply(&dense);
    }
    // Error memory is alive (coarse 4-bit quantizer ⇒ nonzero residual).
    assert!(dqgan::util::stats::norm2_sq(&prev_err) > 0.0);
}

#[test]
fn dqgan_8bit_matches_full_precision_within_slight_degradation() {
    // The paper's headline claim (§4): DQGAN with 1/4-precision gradients
    // produces results comparable to full-precision CPOAdam, with only a
    // slight quality gap. Averaged over seeds (GAN scores are noisy).
    //
    // (The EF-vs-no-EF ablation at *extreme* quantization is validated on
    // the quadratic operator in `algo::dqgan_adam` unit tests, where the
    // EF analysis applies literally; with Adam preconditioning on a GAN at
    // s=1 the interaction is outside the paper's tested regime.)
    let scorer = MlpGan::new(MlpGanConfig::default());
    let mixture = GaussianMixture2D::ring(8, 2.0, 0.1);
    let mut rng = Pcg32::new(5);
    let mut score = |algo: &str, seed: u64| {
        let rep = mlp_cluster(algo, 1200, 2e-3, seed);
        mixture.quality_score(&scorer.sample_generator(&rep.worker0.final_params, 512, &mut rng))
    };
    let seeds = [77u64, 78, 79];
    let q_dq: f32 =
        seeds.iter().map(|&s| score("dqgan-adam:linf8", s)).sum::<f32>() / 3.0;
    let q_fp: f32 = seeds.iter().map(|&s| score("cpoadam", s)).sum::<f32>() / 3.0;
    assert!(
        q_dq < q_fp * 1.35 + 0.1,
        "8-bit DQGAN should be within a slight gap of full precision: \
         dqgan={q_dq} cpoadam={q_fp}"
    );
    // And both must actually have learned something.
    assert!(q_dq < 1.5, "dqgan quality {q_dq}");
}

#[test]
fn quantized_uplink_is_about_4x_smaller() {
    let dq = mlp_cluster("dqgan-adam:linf8", 50, 2e-3, 9);
    let cp = mlp_cluster("cpoadam", 50, 2e-3, 9);
    let ratio = cp.total_bytes_up as f64 / dq.total_bytes_up as f64;
    assert!(
        (3.2..=4.2).contains(&ratio),
        "8-bit uplink ratio should be ≈3.5–4×, got {ratio:.2}"
    );
}

#[test]
fn runs_are_deterministic_given_seed() {
    let a = mlp_cluster("dqgan:linf8", 100, 0.02, 123);
    let b = mlp_cluster("dqgan:linf8", 100, 0.02, 123);
    assert_eq!(a.worker0.final_params, b.worker0.final_params);
    let c = mlp_cluster("dqgan:linf8", 100, 0.02, 124);
    assert_ne!(a.worker0.final_params, c.worker0.final_params);
}
