//! Integration: every experiment harness runs end-to-end in fast mode and
//! produces its CSV — the "figures regenerate" guarantee.

use std::path::Path;

fn results(file: &str) -> bool {
    Path::new("results").join(file).exists()
}

#[test]
fn bilinear_harness_runs_and_writes_csv() {
    dqgan::exp::run("bilinear", true).unwrap();
    assert!(results("bilinear.csv"));
}

#[test]
fn lemma1_harness_validates_the_bound() {
    // run() itself asserts the Lemma-1 bound holds for every compressor.
    dqgan::exp::run("lemma1", true).unwrap();
    assert!(results("lemma1.csv"));
}

#[test]
fn thm3_harness_runs_and_writes_csv() {
    dqgan::exp::run("thm3", true).unwrap();
    assert!(results("thm3.csv"));
}

#[test]
fn synthetic_harness_runs_and_writes_csv() {
    dqgan::exp::run("synthetic", true).unwrap();
    assert!(results("synthetic.csv"));
}

#[test]
fn fig4_harness_runs_when_artifacts_present() {
    if !dqgan::runtime::artifacts_dir().join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    dqgan::exp::run("fig4", true).unwrap();
    assert!(results("fig4.csv"));
}

#[test]
fn unknown_experiment_is_an_error() {
    assert!(dqgan::exp::run("figNaN", true).is_err());
}
