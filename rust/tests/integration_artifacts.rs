//! Integration: the Rust runtime drives the AOT artifacts end-to-end and
//! the XLA path agrees with the native implementations.
//!
//! Requires `make artifacts` (skips with a message otherwise).

use dqgan::data::{GaussianMixture2D, SynthImages, IMG_LEN};
use dqgan::grad::GradientSource;
use dqgan::metrics::{FeatureNet, FEATURE_DIM, NUM_CLASSES};
use dqgan::model::{MlpGan, MlpGanConfig};
use dqgan::runtime::{artifacts_dir, Runtime, XlaFeatureNet, XlaGradSource, XlaQuantizer, XlaSampler};
use dqgan::util::rng::Pcg32;

fn runtime_or_skip() -> Option<Runtime> {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::from_default_dir().expect("runtime"))
}

#[test]
fn manifest_loads_and_lists_all_artifacts() {
    let Some(rt) = runtime_or_skip() else { return };
    for name in [
        "mlp_gan_grad",
        "mlp_gan_sample",
        "dcgan_grad",
        "dcgan_sample",
        "quantize_ef_mlp",
        "quantize_ef_dcgan",
        "omd_half_mlp",
        "omd_half_dcgan",
        "feature_net",
    ] {
        assert!(rt.manifest().get(name).is_ok(), "missing artifact {name}");
    }
}

#[test]
fn xla_mlp_grad_matches_native_analytic_gradient() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut src = XlaGradSource::mlp(&rt, GaussianMixture2D::ring(8, 2.0, 0.1)).unwrap();
    let batch = src.artifact_batch();
    let mut rng = Pcg32::new(42);
    let w = src.init_params(&mut rng);

    // Native gradient on the SAME minibatch: replicate the artifact's
    // sampling order (z first: batch×nz normals row-major; then data).
    let native = MlpGan::new(MlpGanConfig::default());
    assert_eq!(native.layout.total_len(), src.dim());

    // Run the XLA grad with a cloned RNG so we can reproduce ξ natively.
    let mut rng_x = Pcg32::new(777);
    let mut rng_n = rng_x.clone();
    let mut g_xla = vec![0.0; src.dim()];
    src.grad(&w, batch, &mut rng_x, &mut g_xla).unwrap();

    let nz = 4; // MlpGanConfig::default().noise_dim
    let zs: Vec<Vec<f32>> = (0..batch).map(|_| rng_n.normal_vec(nz)).collect();
    let xs: Vec<[f32; 2]> = (0..batch).map(|_| native.data.sample(&mut rng_n)).collect();
    let mut g_native = vec![0.0; src.dim()];
    native.grad_with_samples(&w, &zs, &xs, &mut g_native);

    let mut max_rel = 0.0f32;
    for (a, b) in g_xla.iter().zip(&g_native) {
        let rel = (a - b).abs() / b.abs().max(1e-3);
        max_rel = max_rel.max(rel);
    }
    assert!(
        max_rel < 2e-2,
        "XLA and native MLP-GAN gradients disagree: max rel err {max_rel}"
    );
}

#[test]
fn xla_dcgan_grad_runs_and_is_finite() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut src = XlaGradSource::dcgan(&rt, SynthImages::cifar_like(1)).unwrap();
    let batch = src.artifact_batch();
    let mut rng = Pcg32::new(7);
    let w = src.init_params(&mut rng);
    let mut g = vec![0.0; src.dim()];
    let meta = src.grad(&w, batch, &mut rng, &mut g).unwrap();
    assert!(g.iter().all(|x| x.is_finite()));
    assert!(g.iter().any(|&x| x != 0.0));
    assert!(meta.loss_g.unwrap().is_finite());
    assert!(meta.loss_d.unwrap().is_finite());
}

#[test]
fn xla_quantizer_satisfies_ef_identity_and_grid() {
    let Some(rt) = runtime_or_skip() else { return };
    let q = XlaQuantizer::new(&rt, "quantize_ef_mlp").unwrap();
    let mut rng = Pcg32::new(3);
    let v = rng.normal_vec(q.dim());
    let (qv, e) = q.quantize_ef(&v, &mut rng).unwrap();
    // EF identity: p = q + e exactly.
    for i in 0..v.len() {
        assert!((qv[i] + e[i] - v[i]).abs() < 1e-6, "EF identity broken at {i}");
    }
    // δ-contract at 8 bits: the quantization error is tiny on Gaussians.
    let err: f32 = e.iter().map(|x| x * x).sum();
    let norm: f32 = v.iter().map(|x| x * x).sum();
    assert!(err / norm < 0.01, "err ratio {}", err / norm);
}

#[test]
fn xla_and_native_quantizers_agree_in_distribution() {
    let Some(rt) = runtime_or_skip() else { return };
    use dqgan::compress::{Compressor, LinfStochastic};
    let xq = XlaQuantizer::new(&rt, "quantize_ef_mlp").unwrap();
    let spec = rt.manifest().get("quantize_ef_mlp").unwrap();
    let levels = spec.meta_usize("levels").unwrap() as u32;
    let block = spec.meta_usize("block").unwrap();
    let nq = LinfStochastic::new(levels).with_block(block);
    let mut rng = Pcg32::new(11);
    let v = rng.normal_vec(xq.dim());
    // Different RNG draws ⇒ compare E[Q(v)] over repetitions.
    let reps = 50;
    let mut mean_x = vec![0.0f64; v.len()];
    let mut mean_n = vec![0.0f64; v.len()];
    for _ in 0..reps {
        let (qx, _) = xq.quantize_ef(&v, &mut rng).unwrap();
        let qn = nq.compress_vec(&v, &mut rng);
        for i in 0..v.len() {
            mean_x[i] += qx[i] as f64 / reps as f64;
            mean_n[i] += qn[i] as f64 / reps as f64;
        }
    }
    // Both are unbiased for v — their means must agree within noise.
    let mut max_diff = 0.0f64;
    for i in 0..v.len() {
        max_diff = max_diff.max((mean_x[i] - mean_n[i]).abs());
    }
    assert!(max_diff < 0.05, "distributional disagreement: {max_diff}");
}

#[test]
fn omd_half_artifact_matches_native_update() {
    let Some(rt) = runtime_or_skip() else { return };
    let exe = rt.load("omd_half_mlp").unwrap();
    let n = exe.spec.inputs[0].numel();
    let mut rng = Pcg32::new(5);
    let w = rng.normal_vec(n);
    let f = rng.normal_vec(n);
    let e = rng.normal_vec(n);
    let eta = [0.05f32];
    let out = exe.run_f32(&[&w, &f, &e, &eta]).unwrap().remove(0);
    for i in 0..n {
        let want = w[i] - (0.05 * f[i] + e[i]);
        assert!((out[i] - want).abs() < 1e-5, "i={i}: {} vs {want}", out[i]);
    }
}

#[test]
fn xla_feature_net_matches_native_features() {
    let Some(rt) = runtime_or_skip() else { return };
    let xnet = XlaFeatureNet::new(&rt).unwrap();
    let native = FeatureNet::new();
    let ds = SynthImages::cifar_like(4);
    let mut rng = Pcg32::new(9);
    let (imgs, _) = ds.sample_batch(xnet.batch, &mut rng);
    assert_eq!(imgs.len(), xnet.batch * IMG_LEN);
    let (fx, lx) = xnet.score(&imgs).unwrap();
    let (fn_, ln_) = native.features_batch(&imgs);
    assert_eq!(fx.len(), xnet.batch * FEATURE_DIM);
    assert_eq!(lx.len(), xnet.batch * NUM_CLASSES);
    for (a, b) in fx.iter().zip(&fn_) {
        assert!((a - b).abs() < 1e-3 * b.abs().max(1.0), "feature mismatch {a} vs {b}");
    }
    for (a, b) in lx.iter().zip(&ln_) {
        assert!((a - b).abs() < 1e-3 * b.abs().max(1.0), "logit mismatch {a} vs {b}");
    }
}

#[test]
fn xla_sampler_produces_images_in_range() {
    let Some(rt) = runtime_or_skip() else { return };
    let sampler = XlaSampler::new(&rt, "dcgan_sample").unwrap();
    let mut src = XlaGradSource::dcgan(&rt, SynthImages::cifar_like(2)).unwrap();
    let mut rng = Pcg32::new(21);
    let w = src.init_params(&mut rng);
    let imgs = sampler.sample(&w, &mut rng).unwrap();
    assert_eq!(imgs.len(), sampler.sample_n * IMG_LEN);
    assert!(imgs.iter().all(|&p| (-1.0..=1.0).contains(&p)));
}
