//! Integration: the readiness-loop transport (`--transport evloop`) —
//! one leader-side event loop driving every worker connection, with
//! ack-based applied-broadcast flow control — locked down against the
//! per-worker-thread baseline (`--transport threads`) by frame-level
//! equivalence: at M ∈ {64, 512, 4096} in-process workers, every
//! worker's downlink frame stream (kind, round and payload bytes)
//! through a real [`serve_rounds_with`] run must be bitwise-identical
//! across the two transports, and the data-plane byte accounting
//! (uplink/downlink totals) must agree exactly — only the control
//! plane (ack frames) may differ, by exactly M·rounds ack frames.
//!
//! Workers are driven by a fixed-size feeder-thread pool (thousands of
//! in-process worker ends, a handful of OS threads), so the test itself
//! scales the way the evloop leader does.

use dqgan::comm::inproc::InprocWorkerEnd;
use dqgan::comm::{inproc_cluster, inproc_cluster_evloop, Message, MsgKind, ServerEnd, WorkerEnd};
use dqgan::compress::{Compressor, Identity};
use dqgan::config::AggregatorConfig;
use dqgan::ps::{serve_rounds_with, Decoder};
use std::sync::Arc;

const DIM: usize = 16;
const ROUNDS: u64 = 3;
const FEEDERS: usize = 8;

fn identity_decoder() -> Decoder {
    Arc::new(|bytes: &[u8], out: &mut [f32]| Identity.decode_into(bytes, out))
}

/// Deterministic per-(worker, round, lane) payload value — every arm
/// feeds byte-identical uplink streams.
fn lane_value(worker: u32, round: u64, lane: usize) -> f32 {
    (worker as f32 + 1.0) * 1e-3 * (lane as f32 + 1.0) - round as f32 * 0.25
}

/// Drive one feeder's chunk of worker ends through all rounds: send
/// every payload, then collect every broadcast (acking each as
/// *applied* — a no-op on the threaded transport), then drain the
/// shutdown frames. Returns each worker's downlink frames verbatim —
/// the bytes under comparison.
fn drive_chunk(ends: &mut [InprocWorkerEnd]) -> Vec<Vec<Message>> {
    let mut got = vec![Vec::new(); ends.len()];
    for round in 0..ROUNDS {
        for end in ends.iter_mut() {
            let id = end.id();
            let v: Vec<f32> = (0..DIM).map(|j| lane_value(id, round, j)).collect();
            let mut wire = Vec::new();
            Identity.encode(&v, &mut wire);
            end.send(Message::payload(id, round, wire)).unwrap();
        }
        for (end, frames) in ends.iter_mut().zip(got.iter_mut()) {
            let b = end.recv().unwrap();
            assert_eq!(b.round, round);
            frames.push(b);
            end.ack(round).unwrap();
        }
    }
    for end in ends.iter_mut() {
        assert_eq!(end.recv().unwrap().kind, MsgKind::Shutdown);
    }
    got
}

/// One full [`serve_rounds_with`] run over either in-process transport;
/// returns each worker's received frames (worker-id order) plus the
/// (up, down, ctrl) byte totals.
fn run_arm(
    m: usize,
    evloop: bool,
    agg: AggregatorConfig,
) -> (Vec<Vec<Message>>, u64, u64, u64) {
    let (mut server, ends, counter): (Box<dyn ServerEnd>, _, _) = if evloop {
        let (s, e, c) = inproc_cluster_evloop(m);
        (Box::new(s), e, c)
    } else {
        let (s, e, c) = inproc_cluster(m);
        (Box::new(s), e, c)
    };
    // Contiguous chunks keep worker-id order after the flatten below.
    let chunk = m.div_ceil(FEEDERS.min(m));
    let mut chunks: Vec<Vec<InprocWorkerEnd>> = Vec::new();
    let mut it = ends.into_iter();
    loop {
        let c: Vec<_> = it.by_ref().take(chunk).collect();
        if c.is_empty() {
            break;
        }
        chunks.push(c);
    }
    let frames = std::thread::scope(|s| {
        let handles: Vec<_> = chunks
            .into_iter()
            .map(|mut c| s.spawn(move || drive_chunk(&mut c)))
            .collect();
        serve_rounds_with(&mut *server, identity_decoder(), DIM, ROUNDS, agg, |_| {})
            .unwrap();
        handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect::<Vec<Vec<Message>>>()
    });
    drop(server);
    (frames, counter.up_total(), counter.down_total(), counter.ctrl_total())
}

/// The equivalence property at one M: identical frame streams, identical
/// data-plane byte totals, and an evloop control plane of exactly one
/// ack frame per (worker, round).
fn assert_transports_agree(m: usize, threads_agg: AggregatorConfig) {
    let (reference, t_up, t_down, t_ctrl) = run_arm(m, false, threads_agg);
    let (got, e_up, e_down, e_ctrl) =
        run_arm(m, true, AggregatorConfig::pipelined_with_depth(2));
    assert_eq!(got.len(), m);
    for (w, (g, r)) in got.iter().zip(reference.iter()).enumerate() {
        assert_eq!(g, r, "worker {w} downlink frames diverge at M={m}");
    }
    assert_eq!((e_up, e_down), (t_up, t_down), "data-plane bytes diverge at M={m}");
    assert_eq!(t_ctrl, 0, "threaded transport has no control plane");
    let ack_len = Message::ack(0, 0).frame_len() as u64;
    assert_eq!(e_ctrl, m as u64 * ROUNDS * ack_len, "one ack per applied broadcast");
}

#[test]
fn evloop_matches_threads_bitwise_at_m64() {
    // Small-M half: both arms run the full pipelined engine (the
    // threaded transport's 64-writer-thread army is still affordable
    // here), so the comparison covers async broadcasts + ack-bounded
    // depth against writer-queue-bounded depth.
    assert_transports_agree(64, AggregatorConfig::pipelined_with_depth(2));
}

#[test]
fn evloop_matches_threads_bitwise_at_m512() {
    // At-scale halves: the threaded reference arm runs the streaming
    // engine's synchronous broadcast path (bitwise-identical to its
    // pipelined path by the integration_pipeline suite) precisely
    // because a 512/4096-thread writer army is the pathology the
    // readiness loop exists to remove — the evloop arm still runs
    // fully pipelined with ack flow control.
    assert_transports_agree(512, AggregatorConfig::streaming());
}

#[test]
fn evloop_matches_threads_bitwise_at_m4096() {
    assert_transports_agree(4096, AggregatorConfig::streaming());
}
