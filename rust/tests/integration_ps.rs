//! Integration: the parameter-server runtime over both transports, with
//! byte accounting, worker synchronization and failure handling.

use dqgan::algo::AlgoKind;
use dqgan::comm::tcp::{TcpServerBuilder, TcpWorkerEnd};
use dqgan::comm::{inproc_cluster, Message, MsgKind, ServerEnd, WorkerEnd};
use dqgan::compress::{Compressor, Identity};
use dqgan::config::{AggMode, AggregatorConfig};
use dqgan::grad::QuadraticOperator;
use dqgan::optim::LrSchedule;
use dqgan::ps::{
    run_cluster, serve_rounds, serve_rounds_with, worker_loop, Aggregator, ClusterConfig,
    Decoder,
};
use dqgan::util::rng::Pcg32;
use dqgan::util::threadpool::CountdownLatch;
use std::sync::Arc;

#[test]
fn full_cluster_all_algorithms_converge_on_quadratic() {
    for algo in ["dqgan:linf8", "dqgan-adam:linf8", "cpoadam", "cpoadam-gq:linf8"] {
        let cfg = ClusterConfig {
            algo: AlgoKind::parse(algo).unwrap(),
            workers: 3,
            batch: 8,
            rounds: 700,
            lr: LrSchedule::constant(if algo.starts_with("dqgan:") { 0.1 } else { 0.03 }),
            seed: 11,
            eval_every: 0,
            keep_stats: false,
            agg: Default::default(),
            transport: Default::default(),
            chaos_kill: None,
        };
        let report = run_cluster(&cfg, |_m| {
            let mut rng = Pcg32::new(321);
            Ok(Box::new(QuadraticOperator::new(12, 0.1, &mut rng)))
        })
        .unwrap_or_else(|e| panic!("{algo}: {e}"));
        let target = {
            let mut rng = Pcg32::new(321);
            QuadraticOperator::new(12, 0.1, &mut rng).target
        };
        let dist =
            dqgan::util::stats::dist2_sq(&report.worker0.final_params, &target).sqrt();
        assert!(dist < 0.5, "{algo}: dist to optimum {dist}");
    }
}

#[test]
fn byte_accounting_matches_algorithm_prediction() {
    let algo = AlgoKind::parse("dqgan:linf8").unwrap();
    let dim = 256;
    let rounds = 10u64;
    let workers = 3usize;
    let cfg = ClusterConfig {
        algo: algo.clone(),
        workers,
        batch: 4,
        rounds,
        lr: LrSchedule::constant(0.05),
        seed: 5,
        eval_every: 0,
        keep_stats: false,
        agg: Default::default(),
        transport: Default::default(),
        chaos_kill: None,
    };
    let report = run_cluster(&cfg, |_m| {
        let mut rng = Pcg32::new(9);
        Ok(Box::new(QuadraticOperator::new(dim, 0.1, &mut rng)))
    })
    .unwrap();
    let expected = algo.uplink_bytes(dim) as u64 * rounds * workers as u64;
    assert_eq!(report.total_bytes_up, expected);
}

#[test]
fn tcp_transport_runs_a_real_training_round_trip() {
    // Full PS protocol over real sockets: 2 workers, 20 rounds of DQGAN.
    let m = 2usize;
    let rounds = 20u64;
    let dim = 16usize;
    let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
    let addr = builder.addr();
    let algo = AlgoKind::parse("dqgan:linf8").unwrap();

    let mut worker_handles = Vec::new();
    let mut seed_rng = Pcg32::new(88);
    let w0 = {
        let op = QuadraticOperator::new(dim, 0.1, &mut seed_rng);
        use dqgan::grad::GradientSource;
        op.init_params(&mut seed_rng)
    };
    for id in 0..m as u32 {
        let w0 = w0.clone();
        let algo = algo.clone();
        worker_handles.push(std::thread::spawn(move || {
            let mut end = TcpWorkerEnd::connect(&addr.to_string(), id).unwrap();
            let mut worker = algo.build_worker(w0, LrSchedule::constant(0.05));
            let mut rng = Pcg32::new(100 + id as u64);
            let mut src = {
                let mut r = Pcg32::new(55);
                QuadraticOperator::new(dim, 0.1, &mut r)
            };
            worker_loop(
                &mut end,
                worker.as_mut(),
                &mut src,
                4,
                rounds,
                &mut rng,
                false,
                None,
            )
            .unwrap()
        }));
    }
    let mut server = builder.accept(m).unwrap();
    let decoder = algo.decoder();
    let records = serve_rounds(&mut server, decoder, dim, rounds, |_| {}).unwrap();
    assert_eq!(records.len(), rounds as usize);
    let summaries: Vec<_> =
        worker_handles.into_iter().map(|h| h.join().unwrap()).collect();
    // All workers end with identical parameters (synchronous PS invariant).
    assert_eq!(summaries[0].final_params, summaries[1].final_params);
    assert!(server.counter().up_total() > 0);
}

#[test]
fn streaming_decodes_early_arrivals_before_the_straggler_lands() {
    // The headline overlap property, proven by construction rather than
    // timing: worker 3 refuses to send until the leader has decoded the
    // other three payloads. Only a decode-on-arrival engine can make that
    // progress; a gather-everything-first barrier would leave the gate
    // closed (the bounded wait then turns the deadlock into a
    // deterministic assertion failure instead of a CI hang).
    use std::sync::atomic::{AtomicBool, Ordering};
    let m = 4usize;
    let d = 64usize;
    let (mut server, workers, _) = inproc_cluster(m);
    let gate = Arc::new(CountdownLatch::new(1));
    let released = Arc::new(AtomicBool::new(false));
    let mut handles = Vec::new();
    for (i, mut w) in workers.into_iter().enumerate() {
        let gate = Arc::clone(&gate);
        let released = Arc::clone(&released);
        handles.push(std::thread::spawn(move || {
            if i == 3 {
                if gate.wait_timeout(std::time::Duration::from_secs(30)) {
                    released.store(true, Ordering::SeqCst);
                }
            }
            let v = vec![i as f32; d];
            let mut wire = Vec::new();
            Identity.encode(&v, &mut wire);
            w.send(Message::payload(i as u32, 0, wire)).unwrap();
            let b = w.recv().unwrap();
            assert_eq!(b.kind, MsgKind::Broadcast);
        }));
    }
    let decoder: Decoder = Arc::new(|b: &[u8], out: &mut [f32]| Identity.decode_into(b, out));
    let mut agg = Aggregator::new(AggregatorConfig::streaming(), d, m);
    agg.begin_round(0);
    let mut decoded_before_release = 0usize;
    server
        .recv_round_streaming(&mut |msg| {
            let res = agg.accept(&msg, &decoder);
            if !released.load(Ordering::SeqCst) {
                decoded_before_release += 1;
                if decoded_before_release == m - 1 {
                    // Three payloads already decoded — release the
                    // straggler (exactly once: its own payload arrives
                    // only after it observed the open gate).
                    gate.count_down();
                }
            }
            res
        })
        .unwrap();
    let avg = agg.finish_round().unwrap().to_vec();
    assert_eq!(avg, vec![(0.0 + 1.0 + 2.0 + 3.0) / m as f32; d]);
    server.broadcast(Message::broadcast(0, Vec::new())).unwrap();
    for h in handles {
        h.join().unwrap();
    }
    assert!(
        released.load(Ordering::SeqCst),
        "straggler must have been released by decode progress, not by timeout"
    );
    assert!(
        decoded_before_release >= m - 1,
        "only {decoded_before_release} payloads decoded before the straggler sent"
    );
}

#[test]
fn streaming_cluster_is_bitwise_identical_to_sequential() {
    // End-to-end A/B across the full distributed stack: identical seeds ⇒
    // identical payload streams, and the order-invariant streaming reduce
    // must reproduce the sequential trajectory bit for bit.
    let run = |mode: AggMode| {
        let cfg = ClusterConfig {
            algo: AlgoKind::parse("dqgan:linf8").unwrap(),
            workers: 4,
            batch: 8,
            rounds: 50,
            lr: LrSchedule::constant(0.05),
            seed: 42,
            eval_every: 0,
            keep_stats: false,
            agg: AggregatorConfig { mode, ..Default::default() },
            transport: Default::default(),
            chaos_kill: None,
        };
        run_cluster(&cfg, |_m| {
            let mut rng = Pcg32::new(7);
            Ok(Box::new(QuadraticOperator::new(64, 0.1, &mut rng)))
        })
        .unwrap()
    };
    let seq = run(AggMode::Sequential);
    let stream = run(AggMode::Streaming);
    assert_eq!(seq.worker0.final_params, stream.worker0.final_params);
    assert_eq!(stream.records.len(), 50);
    for r in &stream.records {
        assert!(r.wait_secs >= 0.0 && r.agg_secs >= 0.0);
        assert!(r.wall_secs >= r.wait_secs, "wall {} < wait {}", r.wall_secs, r.wait_secs);
    }
}

#[test]
fn tcp_streaming_mode_trains_over_real_sockets() {
    // Same protocol as the classic TCP test, but the leader runs the
    // event-driven round engine (per-socket reader threads + arrival
    // channel) for all 20 rounds.
    let m = 2usize;
    let rounds = 20u64;
    let dim = 16usize;
    let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
    let addr = builder.addr();
    let algo = AlgoKind::parse("dqgan:linf8").unwrap();

    let mut worker_handles = Vec::new();
    let mut seed_rng = Pcg32::new(88);
    let w0 = {
        let op = QuadraticOperator::new(dim, 0.1, &mut seed_rng);
        use dqgan::grad::GradientSource;
        op.init_params(&mut seed_rng)
    };
    for id in 0..m as u32 {
        let w0 = w0.clone();
        let algo = algo.clone();
        worker_handles.push(std::thread::spawn(move || {
            let mut end = TcpWorkerEnd::connect(&addr.to_string(), id).unwrap();
            let mut worker = algo.build_worker(w0, LrSchedule::constant(0.05));
            let mut rng = Pcg32::new(100 + id as u64);
            let mut src = {
                let mut r = Pcg32::new(55);
                QuadraticOperator::new(dim, 0.1, &mut r)
            };
            let summary = worker_loop(
                &mut end,
                worker.as_mut(),
                &mut src,
                4,
                rounds,
                &mut rng,
                false,
                None,
            )
            .unwrap();
            (summary, end.counter().down_total())
        }));
    }
    let mut server = builder.accept(m).unwrap();
    let decoder = algo.decoder();
    let records = serve_rounds_with(
        &mut server,
        decoder,
        dim,
        rounds,
        AggregatorConfig::streaming(),
        |_| {},
    )
    .unwrap();
    assert_eq!(records.len(), rounds as usize);
    let results: Vec<_> = worker_handles.into_iter().map(|h| h.join().unwrap()).collect();
    // Synchronous PS invariant holds through the streaming engine.
    assert_eq!(results[0].0.final_params, results[1].0.final_params);
    // Worker downlink telemetry counts the broadcast + shutdown frames.
    for (_, down) in &results {
        assert!(*down > 0, "worker downlink bytes must be counted");
    }
    assert!(server.counter().up_total() > 0);
}

#[test]
fn decoded_wire_equals_dense_payload_through_the_server() {
    // The server decodes exactly what the worker computed locally.
    let (mut server, mut workers, _) = inproc_cluster(1);
    let c = dqgan::compress::LinfStochastic::with_bits(8);
    let mut rng = Pcg32::new(2);
    let v = rng.normal_vec(64);
    let mut wire = Vec::new();
    let dense = c.compress_encoded(&v, &mut rng, &mut wire);
    workers[0].send(Message::payload(0, 0, wire)).unwrap();

    let decoder: dqgan::ps::Decoder = {
        let c = dqgan::compress::LinfStochastic::with_bits(8);
        Arc::new(move |b: &[u8], out: &mut [f32]| c.decode_into(b, out))
    };
    let t = std::thread::spawn(move || {
        let msg = workers[0].recv().unwrap();
        assert_eq!(msg.kind, MsgKind::Broadcast);
        let mut r = dqgan::util::bytes::Reader::new(&msg.payload);
        r.f32_vec(64).unwrap()
    });
    serve_rounds(&mut server, decoder, 64, 1, |_| {}).unwrap();
    let avg = t.join().unwrap();
    assert_eq!(avg, dense, "single-worker average must equal the decoded payload");
}

#[test]
fn identity_decoder_round_trips_raw_f32() {
    let mut rng = Pcg32::new(4);
    let v = rng.normal_vec(100);
    let mut wire = Vec::new();
    Identity.encode(&v, &mut wire);
    let back = Identity.decode(&wire, 100).unwrap();
    assert_eq!(v, back);
}
