//! Property tests for the compression subsystem (Theorems 1–2 and the
//! codec/wire invariants), via the in-tree `testutil` framework.

use dqgan::compress::{
    compressor_from_spec, BitReader, BitWriter, Compressor, LinfStochastic, Qsgd, SignScale,
    TernGrad, TopK,
};
use dqgan::testutil::forall;
use dqgan::util::stats::norm2_sq;
use dqgan::{prop_assert, prop_pass};

const SPECS: &[&str] = &[
    "identity",
    "topk(f=0.05)",
    "topk(f=0.3)",
    "qsgd8",
    "qsgd(s=3)",
    "linf8",
    "linf(s=7)",
    "linf(bits=8,block=64)",
    "sign",
    "terngrad",
];

/// Theorem 1 (exact, per-sample): top-k contraction with δ = k/d.
#[test]
fn prop_topk_contraction_is_deterministic() {
    forall("topk per-sample contraction", 300, |g| {
        let f = *g.choose(&[0.01f64, 0.1, 0.5, 0.9, 1.0]);
        let c = TopK::new(f);
        let v = g.vec_normal(1..=512);
        if v.is_empty() {
            prop_pass!();
        }
        let q = c.compress_vec(&v, g.rng());
        let err: f32 = v.iter().zip(&q).map(|(a, b)| (a - b) * (a - b)).sum();
        let bound = (1.0 - c.delta(v.len()).unwrap() as f32) * norm2_sq(&v);
        prop_assert!(err <= bound + 1e-4, "err={err} > bound={bound} (d={}, f={f})", v.len());
        prop_pass!()
    });
}

/// Theorem 2 (in expectation): the stochastic quantizers contract.
/// (TernGrad is deliberately excluded: it is unbiased but NOT a
/// δ-approximate compressor — E‖Q(v)−v‖² = Σ|v_i|(‖v‖∞−|v_i|) exceeds
/// ‖v‖² on typical Gaussian vectors. This property test is what caught
/// that; TernGrad is kept in the library as a comparison codec only.)
#[test]
fn prop_stochastic_quantizers_contract_in_expectation() {
    forall("qsgd/linf expected contraction", 40, |g| {
        let d = g.usize_in(16..=256);
        let v = g.vec_normal(d..=d);
        let denom = norm2_sq(&v) as f64;
        if denom < 1e-12 {
            prop_pass!();
        }
        for c in [
            &Qsgd::with_bits(8) as &dyn Compressor,
            &LinfStochastic::with_bits(8),
        ] {
            let reps = 24;
            let mut mean_ratio = 0.0f64;
            for _ in 0..reps {
                let q = c.compress_vec(&v, g.rng());
                let err: f64 =
                    v.iter().zip(&q).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
                mean_ratio += err / denom / reps as f64;
            }
            prop_assert!(
                mean_ratio < 1.0,
                "{} not δ-approximate: E ratio {mean_ratio} on d={d}",
                c.name()
            );
        }
        prop_pass!()
    });
}

/// Negative result, documented: TernGrad violates Definition 1 on plain
/// Gaussian vectors (E‖Q(v)−v‖² > ‖v‖²), so it is NOT usable as DQGAN's
/// compressor with the paper's convergence guarantee.
#[test]
fn prop_terngrad_is_not_delta_approximate() {
    let violations = std::cell::Cell::new(0usize);
    let trials = 20;
    forall("terngrad violates Definition 1 somewhere", 1, |g| {
        for _ in 0..trials {
            let d = g.usize_in(16..=128);
            let v = g.vec_normal(d..=d);
            let denom = norm2_sq(&v) as f64;
            if denom < 1e-12 {
                continue;
            }
            let reps = 24;
            let mut mean_ratio = 0.0f64;
            for _ in 0..reps {
                let q = TernGrad.compress_vec(&v, g.rng());
                let err: f64 =
                    v.iter().zip(&q).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
                mean_ratio += err / denom / reps as f64;
            }
            if mean_ratio > 1.0 {
                violations.set(violations.get() + 1);
            }
        }
        prop_pass!()
    });
    assert!(
        violations.get() > 0,
        "expected TernGrad to violate the contraction on Gaussian inputs"
    );
}

/// Unbiasedness of the unbiased family: E[Q(v)] ≈ v.
#[test]
fn prop_unbiased_quantizers_are_unbiased() {
    forall("unbiasedness", 20, |g| {
        let d = g.usize_in(8..=64);
        let v = g.vec_normal(d..=d);
        for c in
            [&Qsgd::new(4) as &dyn Compressor, &LinfStochastic::new(4), &TernGrad]
        {
            let reps = 600;
            let mut mean = vec![0.0f64; d];
            for _ in 0..reps {
                let q = c.compress_vec(&v, g.rng());
                for i in 0..d {
                    mean[i] += q[i] as f64 / reps as f64;
                }
            }
            let scale = v.iter().fold(0.0f32, |a, &x| a.max(x.abs())).max(0.1) as f64;
            for i in 0..d {
                prop_assert!(
                    (mean[i] - v[i] as f64).abs() < 0.15 * scale,
                    "{} biased at {i}: E={} v={} (d={d})",
                    c.name(),
                    mean[i],
                    v[i]
                );
            }
        }
        prop_pass!()
    });
}

/// Fused compress_encoded round-trips bit-exactly through decode for every
/// compressor — the invariant the error-feedback state relies on.
#[test]
fn prop_wire_round_trip_bit_exact() {
    forall("wire round trip", 120, |g| {
        let spec = *g.choose(SPECS);
        let c = compressor_from_spec(spec).unwrap();
        let d = g.usize_in(1..=700);
        let v = g.vec_normal(d..=d);
        let mut buf = Vec::new();
        let q = c.compress_encoded(&v, g.rng(), &mut buf);
        prop_assert!(
            buf.len() == c.encoded_size(d),
            "{spec}: encoded {} B ≠ declared {} B (d={d})",
            buf.len(),
            c.encoded_size(d)
        );
        let back = c.decode(&buf, d).unwrap();
        for i in 0..d {
            prop_assert!(
                q[i].to_bits() == back[i].to_bits(),
                "{spec}: bit mismatch at {i}: {} vs {} (d={d})",
                q[i],
                back[i]
            );
        }
        prop_pass!()
    });
}

/// Q(0) = 0 for every compressor (required for Definition 1 at v = 0).
#[test]
fn prop_zero_maps_to_zero() {
    forall("zero preservation", 60, |g| {
        let spec = *g.choose(SPECS);
        let c = compressor_from_spec(spec).unwrap();
        let d = g.usize_in(1..=256);
        let v = vec![0.0f32; d];
        let q = c.compress_vec(&v, g.rng());
        prop_assert!(q.iter().all(|&x| x == 0.0), "{spec}: Q(0) ≠ 0");
        prop_pass!()
    });
}

/// Sign-flip equivariance: Q(−v) has the same error profile as Q(v)
/// (holds for all our schemes since they operate on |v| and sign).
#[test]
fn prop_sign_equivariance_of_deterministic_schemes() {
    forall("sign equivariance (topk/sign)", 100, |g| {
        let d = g.usize_in(2..=128);
        let v = g.vec_normal(d..=d);
        let neg: Vec<f32> = v.iter().map(|x| -x).collect();
        for c in [&TopK::new(0.3) as &dyn Compressor, &SignScale] {
            let q1 = c.compress_vec(&v, g.rng());
            let q2 = c.compress_vec(&neg, g.rng());
            for i in 0..d {
                prop_assert!(
                    (q1[i] + q2[i]).abs() < 1e-5,
                    "{}: not sign-equivariant at {i}",
                    c.name()
                );
            }
        }
        prop_pass!()
    });
}

/// decode() must reject truncated buffers rather than panic or fabricate.
#[test]
fn prop_decode_rejects_truncation() {
    forall("decode truncation", 80, |g| {
        let spec = *g.choose(SPECS);
        let c = compressor_from_spec(spec).unwrap();
        let d = g.usize_in(4..=256);
        let v = g.vec_normal(d..=d);
        let mut buf = Vec::new();
        let _ = c.compress_encoded(&v, g.rng(), &mut buf);
        if buf.len() < 2 {
            prop_pass!();
        }
        let cut = g.usize_in(0..=buf.len().saturating_sub(2));
        // Identity with cut=0 on an empty prefix decodes 0 floats... all
        // schemes must error because d elements can't come from `cut` bytes.
        let res = c.decode(&buf[..cut], d);
        prop_assert!(res.is_err(), "{spec}: decoded from {cut}/{} bytes", buf.len());
        prop_pass!()
    });
}

/// The bit-packing substrate under every sub-byte codec: writer/reader
/// round-trip across **every** width 1..=32 with deliberately unaligned
/// tail lengths (n·width ∤ 8), plus exact bit/byte accounting.
#[test]
fn prop_bit_codec_round_trips_every_width() {
    forall("bit codec width sweep", 300, |g| {
        let width = g.usize_in(1..=32) as u8;
        // Lengths like 1, 7, 257 make the final byte partial for almost
        // every width — the unaligned-tail regime.
        let n = g.usize_in(1..=257);
        let mask: u32 = if width == 32 { u32::MAX } else { (1u32 << width) - 1 };
        let values: Vec<u32> = (0..n).map(|_| g.rng().next_u32() & mask).collect();
        let mut w = BitWriter::with_capacity_bits(n * width as usize);
        for &v in &values {
            w.write(v, width);
        }
        let total_bits = n * width as usize;
        prop_assert!(
            w.bit_len() == total_bits,
            "width={width} n={n}: bit_len {} ≠ {total_bits}",
            w.bit_len()
        );
        let bytes = w.into_bytes();
        prop_assert!(
            bytes.len() == total_bits.div_ceil(8),
            "width={width} n={n}: {} bytes ≠ ceil({total_bits}/8)",
            bytes.len()
        );
        let mut r = BitReader::new(&bytes);
        for (i, &v) in values.iter().enumerate() {
            let got = r.read(width);
            prop_assert!(got.is_ok(), "width={width} n={n}: overrun at {i}");
            let got = got.unwrap();
            prop_assert!(got == v, "width={width} n={n} i={i}: {got} ≠ {v}");
        }
        // Only zero-padding of the final partial byte may remain.
        prop_assert!(
            r.bits_remaining() < 8,
            "width={width} n={n}: {} stray bits",
            r.bits_remaining()
        );
        prop_pass!()
    });
}

/// Deterministic companion: one stream interleaving every width 1..=32
/// back to back (maximally misaligned boundaries).
#[test]
fn bit_codec_interleaves_all_widths_in_one_stream() {
    let mut w = BitWriter::new();
    let mut expect = Vec::new();
    for width in 1..=32u8 {
        let mask: u32 = if width == 32 { u32::MAX } else { (1u32 << width) - 1 };
        let v = 0xDEAD_BEEFu32 & mask;
        w.write(v, width);
        expect.push((v, width));
    }
    let total_bits: usize = (1..=32usize).sum();
    assert_eq!(w.bit_len(), total_bits);
    let bytes = w.into_bytes();
    let mut r = BitReader::new(&bytes);
    for (v, width) in expect {
        assert_eq!(r.read(width).unwrap(), v, "width {width}");
    }
    assert!(r.bits_remaining() < 8);
}

/// Compression ratios: every sub-f32 scheme beats raw f32 on the wire.
#[test]
fn prop_encoded_size_beats_fp32() {
    forall("wire size", 60, |g| {
        let d = g.usize_in(64..=4096);
        for spec in ["qsgd8", "linf8", "sign", "terngrad", "topk(f=0.1)"] {
            let c = compressor_from_spec(spec).unwrap();
            prop_assert!(
                c.encoded_size(d) < 4 * d,
                "{spec}: {} B ≥ raw {} B (d={d})",
                c.encoded_size(d),
                4 * d
            );
        }
        prop_pass!()
    });
}
