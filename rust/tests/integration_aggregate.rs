//! Regression: the sharded and streaming leader aggregation paths are
//! **bitwise identical** to the sequential baseline — the guarantee that
//! makes `--agg sharded|sequential|streaming` a pure performance switch.
//! Exercised over real wire payloads for QSGD, sign and top-k at
//! M ∈ {1, 4, 8} (the streaming engine additionally fed in scrambled
//! arrival order), plus an independent check against the seed's
//! `mean_into` arithmetic.
//!
//! The same contract extends to the reduce *schedule*: `--reduce
//! windowed` (incremental prefix folds during the gather, offloaded
//! close on the pipelined path) must be bitwise identical to `--reduce
//! barrier` over every codec, cluster size, arrival order, and both full
//! and K-of-M partial closes — including that a skipped worker's stale
//! slot bytes never leak into a windowed partial mean.

use dqgan::comm::Message;
use dqgan::compress::compressor_from_spec;
use dqgan::config::{AggMode, AggregatorConfig, ReduceMode};
use dqgan::ps::{Aggregator, Decoder};
use dqgan::tensor::ops;
use dqgan::util::rng::Pcg32;
use std::sync::Arc;

fn decoder_for(spec: &str) -> Decoder {
    let c = compressor_from_spec(spec).unwrap();
    Arc::new(move |bytes: &[u8], out: &mut [f32]| c.decode_into(bytes, out))
}

fn round_payloads(spec: &str, m: usize, d: usize, round: u64, rng: &mut Pcg32) -> Vec<Message> {
    let c = compressor_from_spec(spec).unwrap();
    (0..m)
        .map(|w| {
            let v = rng.normal_vec(d);
            let mut wire = Vec::new();
            c.compress_encoded(&v, rng, &mut wire);
            Message::payload(w as u32, round, wire)
        })
        .collect()
}

#[test]
fn sharded_leader_is_bitwise_identical_to_sequential() {
    let mut rng = Pcg32::new(0xA66_2026);
    for spec in ["qsgd8", "sign", "topk(f=0.1)"] {
        for &m in &[1usize, 4, 8] {
            // Dimensions straddle the shard size (1024 below) so every
            // regime is hit: sub-shard, exact multiple, unaligned tail.
            for &d in &[1usize, 63, 1024, 4096, 100_003] {
                let msgs = round_payloads(spec, m, d, 5, &mut rng);
                let dec = decoder_for(spec);
                let mut seq = Aggregator::new(AggregatorConfig::sequential(), d, m);
                let mut shd = Aggregator::new(
                    AggregatorConfig {
                        mode: AggMode::Sharded,
                        threads: 3,
                        shard_elems: 1024,
                        ..Default::default()
                    },
                    d,
                    m,
                );
                let a = seq.aggregate(5, &msgs, &dec).unwrap().to_vec();
                let b = shd.aggregate(5, &msgs, &dec).unwrap();
                assert_eq!(a.len(), b.len());
                for i in 0..d {
                    assert_eq!(
                        a[i].to_bits(),
                        b[i].to_bits(),
                        "{spec} M={m} d={d}: element {i} differs ({} vs {})",
                        a[i],
                        b[i]
                    );
                }
            }
        }
    }
}

#[test]
fn streaming_leader_is_bitwise_identical_in_any_arrival_order() {
    // Same matrix as above, but through the event-driven
    // begin_round/accept/finish_round engine with a rotated + reversed
    // arrival order per case — arrival order must not change a single bit.
    let mut rng = Pcg32::new(0xA66_2027);
    for spec in ["qsgd8", "sign", "topk(f=0.1)"] {
        for &m in &[1usize, 4, 8] {
            for &d in &[1usize, 63, 1024, 4096, 100_003] {
                let msgs = round_payloads(spec, m, d, 5, &mut rng);
                let dec = decoder_for(spec);
                let mut seq = Aggregator::new(AggregatorConfig::sequential(), d, m);
                let oracle = seq.aggregate(5, &msgs, &dec).unwrap().to_vec();
                let mut stream = Aggregator::new(
                    AggregatorConfig {
                        mode: AggMode::Streaming,
                        threads: 3,
                        shard_elems: 1024,
                        ..Default::default()
                    },
                    d,
                    m,
                );
                stream.begin_round(5);
                // Scrambled arrival: rotate by one, then reverse.
                for i in 0..m {
                    let j = m - 1 - ((i + 1) % m);
                    stream.accept(&msgs[j], &dec).unwrap();
                }
                let avg = stream.finish_round().unwrap();
                for i in 0..d {
                    assert_eq!(
                        oracle[i].to_bits(),
                        avg[i].to_bits(),
                        "{spec} M={m} d={d}: element {i} differs in streaming mode"
                    );
                }
            }
        }
    }
}

#[test]
fn both_paths_reproduce_the_seed_mean_into_arithmetic() {
    // Independent oracle: decode every payload and run the seed's
    // `mean_into` — both aggregator modes must match it bit-for-bit.
    let mut rng = Pcg32::new(77);
    let (m, d) = (8usize, 4096usize);
    for spec in ["qsgd8", "sign", "topk(f=0.1)"] {
        let c = compressor_from_spec(spec).unwrap();
        let msgs = round_payloads(spec, m, d, 0, &mut rng);
        let decoded: Vec<Vec<f32>> =
            msgs.iter().map(|msg| c.decode(&msg.payload, d).unwrap()).collect();
        let refs: Vec<&[f32]> = decoded.iter().map(|v| v.as_slice()).collect();
        let mut oracle = vec![0.0f32; d];
        ops::mean_into(&refs, &mut oracle);

        let dec = decoder_for(spec);
        for cfg in [
            AggregatorConfig::sequential(),
            AggregatorConfig {
                mode: AggMode::Sharded,
                threads: 4,
                shard_elems: 100,
                ..Default::default()
            },
        ] {
            let mode = cfg.mode;
            let mut agg = Aggregator::new(cfg, d, m);
            let avg = agg.aggregate(0, &msgs, &dec).unwrap();
            for i in 0..d {
                assert_eq!(
                    oracle[i].to_bits(),
                    avg[i].to_bits(),
                    "{spec} {mode:?}: element {i} differs from mean_into oracle"
                );
            }
        }
    }
}

fn streaming_cfg(reduce: ReduceMode) -> AggregatorConfig {
    AggregatorConfig {
        mode: AggMode::Streaming,
        reduce,
        threads: 3,
        shard_elems: 1024,
        ..Default::default()
    }
}

/// Deterministic arrival scramble: rotate by `rot`, then reverse.
fn scrambled(m: usize, rot: usize) -> Vec<usize> {
    (0..m).map(|i| m - 1 - ((i + rot) % m)).collect()
}

#[test]
fn windowed_reduce_is_bitwise_identical_to_barrier_over_codecs_and_orders() {
    // The full property matrix of the windowed-reduce acceptance
    // criterion: codecs × M × dimensions (straddling the shard size) ×
    // scrambled arrival orders, full-barrier closes.
    let mut rng = Pcg32::new(0xA66_2028);
    for spec in ["qsgd8", "sign", "topk(f=0.1)"] {
        for &m in &[1usize, 4, 8] {
            for &d in &[1usize, 63, 4096, 100_003] {
                let msgs = round_payloads(spec, m, d, 2, &mut rng);
                let dec = decoder_for(spec);
                for rot in [0usize, 1, m / 2] {
                    let order = scrambled(m, rot);
                    let mut barrier = Aggregator::new(streaming_cfg(ReduceMode::Barrier), d, m);
                    let mut windowed = Aggregator::new(streaming_cfg(ReduceMode::Windowed), d, m);
                    for agg in [&mut barrier, &mut windowed] {
                        agg.begin_round(2);
                        for &j in &order {
                            agg.accept(&msgs[j], &dec).unwrap();
                        }
                    }
                    let a = barrier.finish_round().unwrap().to_vec();
                    let b = windowed.finish_round().unwrap();
                    for i in 0..d {
                        assert_eq!(
                            a[i].to_bits(),
                            b[i].to_bits(),
                            "{spec} M={m} d={d} rot={rot}: element {i} differs"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn windowed_partial_closes_match_barrier_and_never_fold_skipped_slots() {
    // K-of-M partial closes: the windowed engine may only have folded
    // the contiguous arrived prefix; skipped slots — poisoned here with
    // a previous round's payloads — must not be folded into the mean.
    // Aggregators are reused across a warm-up round so every skipped
    // slot really holds stale bytes, then compared against a barrier
    // close of the same subset.
    let mut rng = Pcg32::new(0xA66_2029);
    for spec in ["qsgd8", "sign", "topk(f=0.1)"] {
        for &m in &[4usize, 8] {
            for &d in &[63usize, 4096] {
                let dec = decoder_for(spec);
                let poison = round_payloads(spec, m, d, 0, &mut rng);
                let msgs = round_payloads(spec, m, d, 1, &mut rng);
                // Skip sets: the prefix worker (0), the tail worker, and
                // every odd worker.
                let skip_sets: Vec<Vec<usize>> =
                    vec![vec![0], vec![m - 1], (0..m).filter(|w| w % 2 == 1).collect()];
                for skips in skip_sets {
                    let included: Vec<usize> =
                        (0..m).filter(|w| !skips.contains(w)).collect();
                    let mut barrier = Aggregator::new(streaming_cfg(ReduceMode::Barrier), d, m);
                    let mut windowed = Aggregator::new(streaming_cfg(ReduceMode::Windowed), d, m);
                    for agg in [&mut barrier, &mut windowed] {
                        // Warm-up round 0: every slot (including the ones
                        // about to be skipped) decodes a payload.
                        agg.begin_round(0);
                        for msg in &poison {
                            agg.accept(msg, &dec).unwrap();
                        }
                        agg.finish_round().unwrap();
                        // Round 1: only the included subset arrives, in
                        // reversed order to keep the prefix short.
                        agg.begin_round(1);
                        for &w in included.iter().rev() {
                            agg.accept(&msgs[w], &dec).unwrap();
                        }
                        assert_eq!(agg.arrived_count(), included.len());
                    }
                    let a = barrier.finish_partial().unwrap().to_vec();
                    let b = windowed.finish_partial().unwrap();
                    for i in 0..d {
                        assert_eq!(
                            a[i].to_bits(),
                            b[i].to_bits(),
                            "{spec} M={m} d={d} skips={skips:?}: element {i} differs"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn offloaded_pipelined_windowed_close_is_bitwise_identical_too() {
    // The pipelined + windowed + pool close against the barrier oracle,
    // across several rounds (bank rotation), both full and kofm-style
    // partial closes, in both arrival regimes: in-order arrivals leave
    // an empty tail (the close really detaches onto the pool — the
    // offload is gated to tail_workers ≤ 1), reversed arrivals keep the
    // prefix short (the close runs inline shard-parallel).
    let (m, d) = (4usize, 8192usize); // d·M above the pool cutoff
    for spec in ["qsgd8", "sign", "topk(f=0.1)"] {
        for reversed in [false, true] {
            let dec = decoder_for(spec);
            let mut rng = Pcg32::new(0xA66_202A);
            let mut pipe = Aggregator::new(
                AggregatorConfig {
                    threads: 3,
                    shard_elems: 1024,
                    reduce: ReduceMode::Windowed,
                    ..AggregatorConfig::pipelined()
                },
                d,
                m,
            );
            for round in 0..4u64 {
                let msgs = round_payloads(spec, m, d, round, &mut rng);
                let partial = round % 2 == 1;
                let take = if partial { m - 1 } else { m };
                let mut oracle = Aggregator::new(streaming_cfg(ReduceMode::Barrier), d, m);
                oracle.begin_round(round);
                for msg in msgs.iter().take(take) {
                    oracle.accept(msg, &dec).unwrap();
                }
                let want = if partial {
                    oracle.finish_partial().unwrap().to_vec()
                } else {
                    oracle.finish_round().unwrap().to_vec()
                };
                pipe.begin_round(round);
                let order: Vec<usize> =
                    if reversed { (0..take).rev().collect() } else { (0..take).collect() };
                for &j in &order {
                    pipe.accept(&msgs[j], &dec).unwrap();
                }
                let got = if partial {
                    pipe.finish_partial().unwrap()
                } else {
                    pipe.finish_round().unwrap()
                };
                for i in 0..d {
                    assert_eq!(
                        want[i].to_bits(),
                        got[i].to_bits(),
                        "{spec} reversed={reversed} round {round} partial={partial}: \
                         element {i} differs"
                    );
                }
            }
        }
    }
}

#[test]
fn repeated_rounds_reuse_state_and_stay_deterministic() {
    // Same payload set aggregated twice through one Aggregator (buffer
    // reuse) must equal a fresh Aggregator's output exactly.
    let mut rng = Pcg32::new(9);
    let (m, d) = (4usize, 2048usize);
    let dec = decoder_for("qsgd8");
    let r0 = round_payloads("qsgd8", m, d, 0, &mut rng);
    let r1 = round_payloads("qsgd8", m, d, 1, &mut rng);
    let mut reused = Aggregator::new(AggregatorConfig::default(), d, m);
    reused.aggregate(0, &r0, &dec).unwrap();
    let second = reused.aggregate(1, &r1, &dec).unwrap().to_vec();
    let mut fresh = Aggregator::new(AggregatorConfig::default(), d, m);
    let fresh_second = fresh.aggregate(1, &r1, &dec).unwrap();
    for i in 0..d {
        assert_eq!(second[i].to_bits(), fresh_second[i].to_bits(), "element {i}");
    }
}
