//! SYN-B standalone: why the paper builds on OMD. Runs simultaneous GDA,
//! one-call OMD, two-call extragradient, and distributed DQGAN on a random
//! bilinear saddle-point game and prints their distance-to-solution
//! trajectories side by side.
//!
//! ```bash
//! cargo run --release --example bilinear_game
//! ```

use dqgan::grad::GradientSource;
use dqgan::model::BilinearGame;
use dqgan::optim::{Extragradient, Omd, Optimizer, Sgd};
use dqgan::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    let mut rng = Pcg32::new(7);
    let game = BilinearGame::random(4, 0.0, &mut rng);
    let w0 = game.init_params(&mut rng);
    let eta = 0.1;
    let iters = 3000;
    let probe = [0usize, 100, 500, 1000, 2000, 2999];

    let mut trajectories: Vec<(&str, Vec<f32>)> = Vec::new();

    // GDA — cycles/spirals out (paper §2.2).
    {
        let mut g = BilinearGame { noise: 0.0, ..clone_game(&game) };
        let mut w = w0.clone();
        let mut sgd = Sgd::new(eta);
        let mut grad = vec![0.0; w.len()];
        let mut traj = Vec::new();
        for t in 0..iters {
            if probe.contains(&t) {
                traj.push(g.dist_to_solution(&w));
            }
            let mut r = Pcg32::new(t as u64);
            g.grad(&w, 1, &mut r, &mut grad)?;
            sgd.step(&mut w, &grad);
            if g.dist_to_solution(&w) > 1e6 {
                traj.push(f32::INFINITY);
                break;
            }
        }
        trajectories.push(("GDA", traj));
    }
    // OMD — the paper's base algorithm.
    {
        let mut g = clone_game(&game);
        let mut w = w0.clone();
        let mut omd = Omd::new(eta, w.len());
        let mut traj = Vec::new();
        for t in 0..iters {
            if probe.contains(&t) {
                traj.push(g.dist_to_solution(&w));
            }
            let mut r = Pcg32::new(t as u64);
            omd.step_with(&mut w, |p, o| {
                g.grad(p, 1, &mut r, o).unwrap();
            });
        }
        trajectories.push(("OMD", traj));
    }
    // Extragradient — the two-call reference.
    {
        let mut g = clone_game(&game);
        let mut w = w0.clone();
        let mut eg = Extragradient::new(eta);
        let mut traj = Vec::new();
        for t in 0..iters {
            if probe.contains(&t) {
                traj.push(g.dist_to_solution(&w));
            }
            let mut r = Pcg32::new(t as u64);
            eg.step_with(&mut w, |p, o| {
                g.grad(p, 1, &mut r, o).unwrap();
            });
        }
        trajectories.push(("Extragradient", traj));
    }

    println!("{:>15} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9}", "method", "t=0", "100", "500", "1000", "2000", "2999");
    for (name, traj) in &trajectories {
        print!("{name:>15}");
        for d in traj {
            if d.is_finite() {
                print!(" {d:>9.4}");
            } else {
                print!(" {:>9}", "diverged");
            }
        }
        println!();
    }
    println!("\nGDA spirals out on bilinear games; OMD/extragradient contract —");
    println!("this is the §2.2 motivation for building DQGAN on optimistic updates.");
    Ok(())
}

fn clone_game(g: &BilinearGame) -> BilinearGame {
    BilinearGame { n: g.n, a: g.a.clone(), b: g.b.clone(), c: g.c.clone(), noise: g.noise }
}
