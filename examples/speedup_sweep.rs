//! Figure-4 speedup sweep as a standalone binary: measures the real
//! per-round compute cost on this host, then sweeps workers × network
//! models to show where 8-bit DQGAN overtakes fp32 CPOAdam.
//!
//! ```bash
//! make artifacts && cargo run --release --example speedup_sweep
//! ```

use dqgan::comm::NetworkModel;
use dqgan::exp::fig4::{measure_round, speedup_series};
use dqgan::runtime::Runtime;
use dqgan::telemetry::Table;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::from_default_dir()?;
    println!("measuring per-round compute on this host...");
    let dqgan = measure_round(&rt, true, 4)?;
    let cpo = measure_round(&rt, false, 4)?;
    println!(
        "  DQGAN-8bit : {:.1} ms compute, {} B uplink/round",
        dqgan.t_compute * 1e3,
        dqgan.bytes_up
    );
    println!(
        "  CPOAdam    : {:.1} ms compute, {} B uplink/round",
        cpo.t_compute * 1e3,
        cpo.bytes_up
    );

    let nets: [(&str, NetworkModel); 3] = [
        ("1GbE", NetworkModel::one_gbe()),
        ("10GbE", NetworkModel::ten_gbe()),
        ("100GbE", NetworkModel::hundred_gbe()),
    ];
    let workers = [1usize, 2, 4, 8, 16, 32];
    let mut table = Table::new(&["network", "M", "DQGAN-8bit", "CPOAdam-fp32", "ratio"]);
    for (nname, net) in nets {
        let s_dq = speedup_series(&dqgan, "cifar", "DQGAN-8bit", 50_000, 16, &net, &workers);
        let s_cp = speedup_series(&cpo, "cifar", "CPOAdam-fp32", 50_000, 16, &net, &workers);
        for (a, b) in s_dq.iter().zip(&s_cp) {
            table.row(&[
                nname.to_string(),
                a.workers.to_string(),
                format!("{:.2}", a.speedup),
                format!("{:.2}", b.speedup),
                format!("{:.2}×", a.speedup / b.speedup),
            ]);
        }
    }
    table.print();
    println!("(ratio > 1 ⇒ quantization wins; the gap widens with M and slower networks — Fig. 4's shape)");
    Ok(())
}
