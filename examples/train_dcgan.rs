//! **End-to-end validation driver** (EXPERIMENTS.md §E2E): train the
//! DCGAN on the CIFAR-10-like synthetic image corpus for a few hundred
//! distributed rounds through the complete system —
//!
//!   Rust PS leader ⇄ M worker threads ⇄ XLA `dcgan_grad` artifact
//!   (JAX fwd/bwd with the Pallas matmul inside) → 8-bit linf EF
//!   quantization (DQGAN) → byte-exact wire → averaged broadcast —
//!
//! logging the loss curve and the proxy IS/FID trajectory, proving all
//! three layers compose on a real training workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example train_dcgan -- [rounds] [workers]
//! ```

use dqgan::algo::AlgoKind;
use dqgan::data::SynthImages;
use dqgan::exp::images::score_snapshot;
use dqgan::metrics::FeatureNet;
use dqgan::optim::LrSchedule;
use dqgan::ps::{run_cluster, ClusterConfig};
use dqgan::runtime::{Runtime, XlaGradSource, XlaSampler};
use dqgan::telemetry::{results_dir, CsvWriter};
use dqgan::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let rounds: u64 = argv.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let workers: usize = argv.get(1).and_then(|s| s.parse().ok()).unwrap_or(2);
    let eval_every = (rounds / 10).max(1);
    let seed = 2020u64;

    let cfg = ClusterConfig {
        algo: AlgoKind::parse("dqgan-adam:linf8")?,
        workers,
        batch: 16, // the dcgan_grad artifact's exported batch
        rounds,
        lr: LrSchedule::constant(2e-4),
        seed,
        eval_every,
        keep_stats: true,
        agg: Default::default(),
    };
    println!(
        "e2e: DCGAN (400,708 params) on synth-CIFAR, {} workers × batch 16, {} rounds, DQGAN 8-bit",
        workers, rounds
    );

    let rt = Runtime::from_default_dir()?;
    let report = {
        let rt = rt.clone();
        run_cluster(&cfg, move |m| {
            println!("worker {m}: loading dcgan_grad artifact");
            Ok(Box::new(XlaGradSource::dcgan(&rt, SynthImages::cifar_like(seed))?))
        })?
    };

    // Score every snapshot: proxy IS + FID against a real reference batch.
    let net = FeatureNet::new();
    let ds = SynthImages::cifar_like(seed);
    let n_ref = 192;
    let mut rng = Pcg32::new(seed ^ 0x4EF5);
    let (ref_imgs, _) = ds.sample_batch(n_ref, &mut rng);
    let (ref_feats, _) = net.features_batch(&ref_imgs);
    let sampler = XlaSampler::new(&rt, "dcgan_sample")?;

    let csv_path = results_dir()?.join("e2e_train_dcgan.csv");
    let mut csv = CsvWriter::create(
        &csv_path,
        &["round", "loss_g", "loss_d", "inception_score", "fid"],
    )?;
    println!("\n{:>6} {:>10} {:>10} {:>8} {:>8}", "round", "loss_G", "loss_D", "IS", "FID");
    for ev in &report.evals {
        let (is, fid) =
            score_snapshot(&sampler, &net, &ev.params, &ref_feats, n_ref, 128, &mut rng)?;
        println!(
            "{:>6} {:>10.4} {:>10.4} {:>8.3} {:>8.1}",
            ev.round,
            ev.loss_g.unwrap_or(f32::NAN),
            ev.loss_d.unwrap_or(f32::NAN),
            is,
            fid
        );
        csv.row(&[
            ev.round.to_string(),
            format!("{:.5}", ev.loss_g.unwrap_or(f32::NAN)),
            format!("{:.5}", ev.loss_d.unwrap_or(f32::NAN)),
            format!("{is:.4}"),
            format!("{fid:.3}"),
        ])?;
    }
    println!(
        "\ntrained {} rounds in {:.1}s ({:.0} ms/round), uplink {} ({} per round per worker)",
        report.records.len(),
        report.wall_secs,
        report.mean_round_secs * 1e3,
        dqgan::util::bytes::human_bytes(report.total_bytes_up),
        dqgan::util::bytes::human_bytes(
            report.total_bytes_up / report.records.len() as u64 / workers as u64
        ),
    );
    println!("wrote {}", csv.finish()?);
    Ok(())
}
