//! Quickstart: train a small GAN on a 2-D Gaussian mixture with DQGAN
//! (8-bit quantization + error feedback) on the parameter-server runtime,
//! through the full three-layer stack (Rust PS → XLA artifact → Pallas
//! matmul inside the lowered graph).
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use dqgan::algo::AlgoKind;
use dqgan::data::GaussianMixture2D;
use dqgan::model::{MlpGan, MlpGanConfig};
use dqgan::optim::LrSchedule;
use dqgan::ps::{run_cluster, ClusterConfig};
use dqgan::runtime::{Runtime, XlaGradSource};
use dqgan::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    // 1. The cluster: 4 workers, DQGAN with the paper's 8-bit compressor.
    let cfg = ClusterConfig {
        algo: AlgoKind::parse("dqgan-adam:linf8")?,
        workers: 4,
        batch: 32, // matches the exported mlp_gan_grad artifact
        rounds: 600,
        lr: LrSchedule::constant(2e-3),
        seed: 7,
        eval_every: 100,
        keep_stats: true,
        agg: Default::default(),
    };

    // 2. Gradient source: the AOT-compiled JAX model (PJRT CPU).
    let rt = Runtime::from_default_dir()?;
    let mixture = GaussianMixture2D::ring(8, 2.0, 0.1);
    let report = {
        let mixture = mixture.clone();
        run_cluster(&cfg, move |worker| {
            println!("worker {worker}: loading XLA gradient artifact");
            Ok(Box::new(XlaGradSource::mlp(&rt, mixture.clone())?))
        })?
    };

    // 3. Evaluate: sample the trained generator, check mode coverage.
    let scorer = MlpGan::new(MlpGanConfig::default());
    let mut rng = Pcg32::new(99);
    for ev in &report.evals {
        let pts = scorer.sample_generator(&ev.params, 512, &mut rng);
        println!(
            "round {:>4}: mode coverage {:.2}  quality {:.3}  lossD {:+.4}",
            ev.round,
            mixture.mode_coverage(&pts),
            mixture.quality_score(&pts),
            ev.loss_d.unwrap_or(f32::NAN),
        );
    }
    let final_pts = scorer.sample_generator(&report.worker0.final_params, 1024, &mut rng);
    println!(
        "\nfinal: coverage {:.2}, quality {:.3}, trained in {:.1}s, uplink {}",
        mixture.mode_coverage(&final_pts),
        mixture.quality_score(&final_pts),
        report.wall_secs,
        dqgan::util::bytes::human_bytes(report.total_bytes_up),
    );
    assert!(
        mixture.mode_coverage(&final_pts) >= 0.5,
        "quickstart under-trained — expected ≥ half the modes covered"
    );
    Ok(())
}
