"""AOT pipeline checks: the manifest is consistent and the HLO text is
parseable/round-trippable through the XLA client available here."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.aot import to_hlo_text

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def manifest():
    path = os.path.join(ARTIFACTS, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


class TestManifest:
    def test_every_export_is_present(self):
        m = manifest()
        assert set(m["artifacts"].keys()) == set(model.EXPORTS.keys())
        for name, art in m["artifacts"].items():
            path = os.path.join(ARTIFACTS, art["file"])
            assert os.path.exists(path), f"{name}: missing {art['file']}"
            assert len(art["inputs"]) == len(model.EXPORTS[name]["example"])
            assert art["meta"] == model.EXPORTS[name]["meta"]

    def test_shapes_match_examples(self):
        m = manifest()
        for name, art in m["artifacts"].items():
            for inp, ex in zip(art["inputs"], model.EXPORTS[name]["example"]):
                assert inp["shape"] == list(ex.shape), name
                assert inp["dtype"] == "float32", name

    def test_padding_invariants(self):
        m = manifest()
        for name in ["quantize_ef_mlp", "quantize_ef_dcgan"]:
            meta = m["artifacts"][name]["meta"]
            assert meta["padded_dim"] % meta["block"] == 0
            assert meta["padded_dim"] >= meta["dim"]


class TestHloText:
    def test_lowering_produces_valid_hlo_text(self):
        # Lower a tiny fn and sanity-check the text structure.
        fn = lambda x: (x * 2.0 + 1.0,)
        lowered = jax.jit(fn).lower(jnp.zeros((4,), jnp.float32))
        text = to_hlo_text(lowered)
        assert "HloModule" in text
        assert "f32[4]" in text

    def test_artifact_numerics_via_jax_executable(self):
        # Execute the quantize_ef artifact's source function and verify the
        # EF identity on the exported (padded) shape.
        meta = manifest()["artifacts"]["quantize_ef_mlp"]["meta"]
        n = meta["padded_dim"]
        rng = np.random.default_rng(0)
        p = jnp.array(rng.standard_normal(n).astype(np.float32))
        u = jnp.array(rng.random(n, np.float32))
        q, e = model.quantize_ef_mlp(p, u)
        np.testing.assert_allclose(np.array(q) + np.array(e), np.array(p), atol=1e-6)

    def test_sha_matches_file(self):
        import hashlib

        m = manifest()
        for name, art in m["artifacts"].items():
            with open(os.path.join(ARTIFACTS, art["file"])) as f:
                text = f.read()
            assert hashlib.sha256(text.encode()).hexdigest() == art["sha256"], name
