"""L1 correctness: Pallas kernels (interpret=True) vs pure-jnp oracles,
including hypothesis sweeps over shapes and value distributions."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as mk
from compile.kernels import omd_update as ok
from compile.kernels import quantize as qk
from compile.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


# ------------------------------------------------------------- matmul ----


class TestMatmul:
    def test_exact_small(self):
        x = jnp.array([[1.0, 2.0], [3.0, 4.0]], jnp.float32)
        y = jnp.array([[5.0, 6.0], [7.0, 8.0]], jnp.float32)
        np.testing.assert_allclose(
            np.array(mk.matmul(x, y)), [[19.0, 22.0], [43.0, 50.0]]
        )

    @given(
        m=st.integers(1, 64),
        k=st.integers(1, 64),
        n=st.integers(1, 64),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_arbitrary_shapes(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        x = jnp.array(rng.standard_normal((m, k), np.float32))
        y = jnp.array(rng.standard_normal((k, n), np.float32))
        out = mk.matmul(x, y)
        want = ref.matmul_ref(x, y)
        np.testing.assert_allclose(np.array(out), np.array(want), rtol=1e-4, atol=1e-4)

    def test_tile_boundary_shapes(self):
        # Shapes exactly at and just past the tile sizes.
        for m, k, n in [(128, 128, 128), (129, 128, 127), (128, 129, 1)]:
            rng = np.random.default_rng(m * 1000 + k * 10 + n)
            x = jnp.array(rng.standard_normal((m, k), np.float32))
            y = jnp.array(rng.standard_normal((k, n), np.float32))
            np.testing.assert_allclose(
                np.array(mk.matmul(x, y)),
                np.array(ref.matmul_ref(x, y)),
                rtol=1e-4,
                atol=1e-4,
            )

    def test_gradient_flows_through_kernel(self):
        # custom_vjp correctness: compare against jnp.matmul gradients.
        rng = np.random.default_rng(7)
        x = jnp.array(rng.standard_normal((5, 6), np.float32))
        y = jnp.array(rng.standard_normal((6, 4), np.float32))
        f_pallas = lambda a, b: jnp.sum(jnp.sin(mk.matmul(a, b)))
        f_ref = lambda a, b: jnp.sum(jnp.sin(a @ b))
        gx_p, gy_p = jax.grad(f_pallas, argnums=(0, 1))(x, y)
        gx_r, gy_r = jax.grad(f_ref, argnums=(0, 1))(x, y)
        np.testing.assert_allclose(np.array(gx_p), np.array(gx_r), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.array(gy_p), np.array(gy_r), rtol=1e-4, atol=1e-5)

    def test_mxu_utilization_estimate(self):
        assert mk.mxu_utilization_estimate(128, 128, 128) == 1.0
        assert mk.mxu_utilization_estimate(129, 128, 128) < 0.6


# --------------------------------------------------------- quantize_ef ----


class TestQuantizeEf:
    @given(
        blocks=st.integers(1, 8),
        block=st.sampled_from([128, 256, 1024]),
        levels=st.sampled_from([3, 15, 127]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, blocks, block, levels, seed):
        rng = np.random.default_rng(seed)
        n = blocks * block
        p = jnp.array(rng.standard_normal(n).astype(np.float32))
        u = jnp.array(rng.random(n, np.float32))
        q, e = qk.quantize_ef(p, u, levels=levels, block=block)
        qr, er = ref.quantize_ef_ref(p, u, levels, block)
        np.testing.assert_allclose(np.array(q), np.array(qr), rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(np.array(e), np.array(er), rtol=1e-4, atol=1e-6)

    def test_error_feedback_identity(self):
        # p = q + e exactly (the EF invariant Algorithm 2 line 8 needs).
        rng = np.random.default_rng(3)
        p = jnp.array(rng.standard_normal(2048).astype(np.float32))
        u = jnp.array(rng.random(2048, np.float32))
        q, e = qk.quantize_ef(p, u, levels=127, block=1024)
        np.testing.assert_allclose(np.array(q) + np.array(e), np.array(p), atol=1e-6)

    def test_zero_block_stays_zero(self):
        p = jnp.zeros(1024, jnp.float32)
        u = jnp.full(1024, 0.5, jnp.float32)
        q, e = qk.quantize_ef(p, u, levels=127, block=1024)
        assert np.array(q).max() == 0.0
        assert np.array(e).max() == 0.0

    def test_delta_approximate_contract(self):
        # Definition 1 in expectation: E||Q(p)-p||^2 <= (1-δ)||p||^2.
        rng = np.random.default_rng(11)
        p = jnp.array(rng.standard_normal(4096).astype(np.float32))
        trials, ratio = 30, 0.0
        for t in range(trials):
            u = jnp.array(np.random.default_rng(t).random(4096, np.float32))
            q, _ = qk.quantize_ef(p, u, levels=127, block=1024)
            err = float(jnp.sum((q - p) ** 2))
            ratio += err / float(jnp.sum(p * p)) / trials
        assert ratio < 1.0, f"not delta-approximate: mean ratio {ratio}"
        assert ratio < 0.01  # 8-bit should be nearly lossless on Gaussians

    def test_max_element_exact(self):
        # ||.||_inf scaling represents each block's max exactly.
        p = np.zeros(1024, np.float32)
        p[17] = -3.5
        q, _ = qk.quantize_ef(
            jnp.array(p), jnp.full(1024, 0.5, jnp.float32), levels=127, block=1024
        )
        assert np.array(q)[17] == -3.5


# ----------------------------------------------------------- omd_update ----


class TestOmdHalfStep:
    @given(
        blocks=st.integers(1, 4),
        eta=st.floats(0.0, 1.0, allow_nan=False),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, blocks, eta, seed):
        rng = np.random.default_rng(seed)
        n = blocks * 2048
        w = jnp.array(rng.standard_normal(n).astype(np.float32))
        f = jnp.array(rng.standard_normal(n).astype(np.float32))
        e = jnp.array(rng.standard_normal(n).astype(np.float32))
        out = ok.omd_half_step(w, f, e, eta)
        want = ref.omd_update_ref(w, f, e, jnp.float32(eta))
        np.testing.assert_allclose(np.array(out), np.array(want), rtol=1e-5, atol=1e-6)

    def test_eta_zero_is_w_minus_e(self):
        w = jnp.ones(2048, jnp.float32)
        f = jnp.full(2048, 9.0, jnp.float32)
        e = jnp.full(2048, 0.25, jnp.float32)
        out = ok.omd_half_step(w, f, e, 0.0)
        np.testing.assert_allclose(np.array(out), 0.75)
