"""L2 correctness: GAN models' shapes, losses and gradient structure."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.models import dcgan, feature_net, mlp_gan


class TestMlpGan:
    spec = mlp_gan.MlpGanSpec()

    def _wzx(self, b=8, seed=0):
        rng = np.random.default_rng(seed)
        w = jnp.array(0.1 * rng.standard_normal(self.spec.dim, np.float32))
        z = jnp.array(rng.standard_normal((b, self.spec.noise_dim), np.float32))
        x = jnp.array(rng.standard_normal((b, 2), np.float32))
        return w, z, x

    def test_layout_matches_rust(self):
        # Must agree with rust/src/model/mlp_gan.rs (nz=4, hg=hd=32):
        # θ = 32·4+32+2·32+2 = 226, φ = 32·2+32+32+1 = 129, total 355.
        assert self.spec.theta_dim == 226
        assert self.spec.dim == 355

    def test_operator_shapes_and_finiteness(self):
        w, z, x = self._wzx()
        f, lg, ld = mlp_gan.gan_operator(self.spec, w, z, x)
        assert f.shape == (self.spec.dim,)
        assert bool(jnp.isfinite(f).all())
        assert np.isfinite(float(lg)) and np.isfinite(float(ld))

    def test_operator_blocks_are_partial_gradients(self):
        # θ block of F == ∂L_G/∂θ; φ block == ∂L_D/∂φ (finite differences).
        w, z, x = self._wzx(b=4, seed=3)
        f, _, _ = mlp_gan.gan_operator(self.spec, w, z, x)
        td = self.spec.theta_dim
        eps = 1e-3
        for i in [0, 57, td - 1, td, td + 11, self.spec.dim - 1]:
            wp = w.at[i].add(eps)
            wm = w.at[i].add(-eps)
            lgp, ldp = mlp_gan.losses(self.spec, wp, z, x)
            lgm, ldm = mlp_gan.losses(self.spec, wm, z, x)
            fd = (lgp - lgm) / (2 * eps) if i < td else (ldp - ldm) / (2 * eps)
            assert abs(float(fd) - float(f[i])) < 2e-2 * max(abs(float(fd)), 1.0), (
                f"param {i}: fd={float(fd)} vs F={float(f[i])}"
            )

    def test_generator_sample_shape(self):
        w, z, _ = self._wzx()
        out = mlp_gan.sample_generator(self.spec, w, z)
        assert out.shape == (z.shape[0], 2)


class TestDcgan:
    spec = dcgan.DcganSpec()

    def test_generator_output_range_and_shape(self):
        w = dcgan.init_params(self.spec, jax.random.PRNGKey(1))
        z = jax.random.normal(jax.random.PRNGKey(2), (2, self.spec.noise_dim))
        img = dcgan.sample_generator(self.spec, w, z)
        assert img.shape == (2, 3, 32, 32)
        assert float(jnp.abs(img).max()) <= 1.0

    def test_operator_shapes(self):
        w = dcgan.init_params(self.spec, jax.random.PRNGKey(3))
        z = jax.random.normal(jax.random.PRNGKey(4), (2, self.spec.noise_dim))
        x = jax.random.normal(jax.random.PRNGKey(5), (2, 3, 32, 32))
        f, lg, ld = dcgan.gan_operator(self.spec, w, z, x)
        assert f.shape == (self.spec.dim,)
        assert bool(jnp.isfinite(f).all())

    def test_theta_block_ignores_real_data(self):
        # ∂L_G/∂θ does not depend on x_real — a structural property of
        # eq. 6 the operator must preserve.
        w = dcgan.init_params(self.spec, jax.random.PRNGKey(6))
        z = jax.random.normal(jax.random.PRNGKey(7), (2, self.spec.noise_dim))
        x1 = jax.random.normal(jax.random.PRNGKey(8), (2, 3, 32, 32))
        x2 = jax.random.normal(jax.random.PRNGKey(9), (2, 3, 32, 32))
        td = self.spec.theta_dim
        f1, _, _ = dcgan.gan_operator(self.spec, w, z, x1)
        f2, _, _ = dcgan.gan_operator(self.spec, w, z, x2)
        np.testing.assert_allclose(np.array(f1[:td]), np.array(f2[:td]), atol=1e-6)
        assert float(jnp.abs(f1[td:] - f2[td:]).max()) > 1e-6


class TestFeatureNet:
    def test_shapes(self):
        key = jax.random.PRNGKey(0)
        weights = []
        for _, shape in feature_net.weight_shapes():
            key, sub = jax.random.split(key)
            weights.append(jax.random.normal(sub, shape, jnp.float32) * 0.1)
        imgs = jax.random.normal(key, (5, 3, 32, 32), jnp.float32)
        feat, logits = feature_net.features(imgs, *weights)
        assert feat.shape == (5, feature_net.FEATURE_DIM)
        assert logits.shape == (5, feature_net.NUM_CLASSES)

    def test_relu_and_pool_semantics(self):
        # All-zero weights → features = 0, logits = bias.
        ws = [jnp.zeros(s, jnp.float32) for _, s in feature_net.weight_shapes()]
        imgs = jnp.ones((2, 3, 32, 32), jnp.float32)
        feat, logits = feature_net.features(imgs, *ws)
        assert float(jnp.abs(feat).max()) == 0.0
        assert float(jnp.abs(logits).max()) == 0.0
