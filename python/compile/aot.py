"""AOT lowering: every function in ``model.EXPORTS`` → HLO **text** +
``manifest.json``.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the
interchange format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction
ids which xla_extension 0.5.1 (the version the published ``xla`` crate
binds) rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out ../artifacts
Python runs ONLY here (build time); the Rust binary is self-contained
afterwards.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def shape_entry(x):
    return {"shape": list(x.shape), "dtype": str(x.dtype)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--only", default=None, help="comma-separated subset of artifact names"
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    manifest = {"artifacts": {}, "format": "hlo-text", "jax": jax.__version__}
    for name, entry in model.EXPORTS.items():
        if only is not None and name not in only:
            continue
        fn, example = entry["fn"], entry["example"]
        print(f"[aot] lowering {name} ...", flush=True)
        lowered = jax.jit(fn).lower(*example)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out, fname)
        with open(path, "w") as f:
            f.write(text)
        outputs = [shape_entry(x) for x in jax.eval_shape(fn, *example)]
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": [shape_entry(x) for x in example],
            "outputs": outputs,
            "meta": entry["meta"],
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        }
        print(f"[aot]   {fname}: {len(text)} chars, "
              f"{len(example)} inputs, {len(outputs)} outputs", flush=True)

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] wrote {mpath} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    sys.exit(main())
