"""Layer-2 JAX model: DCGAN-lite on 32×32×3 images (the Figures 2-3
workload), WGAN losses (paper eq. 3/6/7), Radford et al. [35] architecture
scaled to this testbed.

    G: z[B,nz] → dense(nz→128·4·4) → 3×(convT 4×4 stride 2) → tanh → [B,3,32,32]
    D: x[B,3,32,32] → 3×(conv 4×4 stride 2, leaky-relu) → dense(2048→1)

The dense layers run through the Pallas matmul kernel; the convolutions
lower to native XLA convolutions. The exported operator has the same
(w, z, x) → (F, L_G, L_D) contract as the MLP GAN.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..kernels.matmul import matmul

IMG_C, IMG_H, IMG_W = 3, 32, 32


@dataclass(frozen=True)
class DcganSpec:
    noise_dim: int = 32
    base: int = 32  # channel multiplier: G/D widths are base·{4,2,1}
    critic_l2: float = 1e-2

    def shapes(self):
        nz, b = self.noise_dim, self.base
        g4, g2, g1 = 4 * b, 2 * b, b
        return [
            # generator (θ)
            ("gen.fc.w", (g4 * 4 * 4, nz)),
            ("gen.fc.b", (g4 * 4 * 4,)),
            ("gen.ct1.w", (g4, g2, 4, 4)),  # convT: (in, out, kh, kw)
            ("gen.ct1.b", (g2,)),
            ("gen.ct2.w", (g2, g1, 4, 4)),
            ("gen.ct2.b", (g1,)),
            ("gen.ct3.w", (g1, IMG_C, 4, 4)),
            ("gen.ct3.b", (IMG_C,)),
            # discriminator (φ)
            ("disc.c1.w", (g1, IMG_C, 4, 4)),  # conv: (out, in, kh, kw)
            ("disc.c1.b", (g1,)),
            ("disc.c2.w", (g2, g1, 4, 4)),
            ("disc.c2.b", (g2,)),
            ("disc.c3.w", (g4, g2, 4, 4)),
            ("disc.c3.b", (g4,)),
            ("disc.fc.w", (1, g4 * 4 * 4)),
            ("disc.fc.b", (1,)),
        ]

    @property
    def dim(self):
        n = 0
        for _, shape in self.shapes():
            k = 1
            for s in shape:
                k *= s
            n += k
        return n

    @property
    def theta_dim(self):
        n = 0
        for name, shape in self.shapes():
            if not name.startswith("gen."):
                continue
            k = 1
            for s in shape:
                k *= s
            n += k
        return n

    def unflatten(self, w):
        out = {}
        off = 0
        for name, shape in self.shapes():
            n = 1
            for s in shape:
                n *= s
            out[name] = w[off : off + n].reshape(shape)
            off += n
        return out


def _conv(x, w, b, stride):
    """NCHW conv, 4×4 kernel, pad SAME-ish for stride 2 (pad 1)."""
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=((1, 1), (1, 1)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return y + b[None, :, None, None]


def _conv_t(x, w, b, stride):
    """NCHW transposed conv, 4×4 kernel, stride 2, output 2× spatial."""
    # 'SAME' with kernel 4 / stride 2 gives exact 2× spatial upsampling
    # (JAX's conv_transpose padding is not the PyTorch convention).
    y = jax.lax.conv_transpose(
        x,
        w,
        strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NCHW", "IOHW", "NCHW"),
    )
    return y + b[None, :, None, None]


def _leaky(x):
    return jnp.where(x > 0, x, 0.2 * x)


def generator(spec, p, z):
    """G(z): z [B, nz] → images [B, 3, 32, 32] in (−1, 1)."""
    b4 = 4 * spec.base
    h = matmul(z, p["gen.fc.w"].T) + p["gen.fc.b"]
    h = jnp.maximum(h, 0.0)  # relu
    h = h.reshape(-1, b4, 4, 4)
    h = jnp.maximum(_conv_t(h, p["gen.ct1.w"], p["gen.ct1.b"], 2), 0.0)  # 8×8
    h = jnp.maximum(_conv_t(h, p["gen.ct2.w"], p["gen.ct2.b"], 2), 0.0)  # 16×16
    x = _conv_t(h, p["gen.ct3.w"], p["gen.ct3.b"], 2)  # 32×32
    return jnp.tanh(x)


def critic(spec, p, x):
    """D(x): images [B, 3, 32, 32] → scores [B]."""
    h = _leaky(_conv(x, p["disc.c1.w"], p["disc.c1.b"], 2))  # 16×16
    h = _leaky(_conv(h, p["disc.c2.w"], p["disc.c2.b"], 2))  # 8×8
    h = _leaky(_conv(h, p["disc.c3.w"], p["disc.c3.b"], 2))  # 4×4
    h = h.reshape(h.shape[0], -1)
    y = matmul(h, p["disc.fc.w"].T) + p["disc.fc.b"]
    return y[:, 0]


def losses(spec, w, z, x_real):
    p = spec.unflatten(w)
    x_fake = generator(spec, p, z)
    y_fake = critic(spec, p, x_fake)
    y_real = critic(spec, p, x_real)
    loss_g = -jnp.mean(y_fake)
    phi = w[spec.theta_dim :]
    loss_d = -jnp.mean(y_real) + jnp.mean(y_fake) + 0.5 * spec.critic_l2 * jnp.sum(
        phi * phi
    )
    return loss_g, loss_d


def gan_operator(spec, w, z, x_real):
    """F(w; ξ) = [∂L_G/∂θ ; ∂L_D/∂φ] plus the losses."""
    g_lg = jax.grad(lambda w_: losses(spec, w_, z, x_real)[0])(w)
    g_ld = jax.grad(lambda w_: losses(spec, w_, z, x_real)[1])(w)
    td = spec.theta_dim
    f = jnp.concatenate([g_lg[:td], g_ld[td:]])
    lg, ld = losses(spec, w, z, x_real)
    return f, lg, ld


def sample_generator(spec, w, z):
    return generator(spec, spec.unflatten(w), z)


def init_params(spec, key):
    """DCGAN init (N(0, 0.02) for convs, He-ish for dense), flat."""
    parts = []
    for name, shape in spec.shapes():
        key, sub = jax.random.split(key)
        n = 1
        for s in shape:
            n *= s
        if name.endswith(".b"):
            parts.append(jnp.zeros(n, jnp.float32))
        elif ".fc." in name:
            fan_in = shape[1] if len(shape) == 2 else shape[0]
            parts.append(
                (jax.random.normal(sub, (n,), jnp.float32) / jnp.sqrt(fan_in))
            )
        else:
            parts.append(0.02 * jax.random.normal(sub, (n,), jnp.float32))
    return jnp.concatenate(parts)
