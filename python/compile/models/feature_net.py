"""Layer-2 JAX mirror of the fixed random-feature net used for proxy
IS/FID (``rust/src/metrics/feature_net.rs``).

The weights are *runtime inputs* of the exported artifact rather than
baked constants: the Rust side passes its own (seed-fixed) weights, which
guarantees both implementations score with the identical embedding without
having to reproduce the Rust PRNG in Python.

Architecture (must match the Rust side):
    conv1: 3→12, 3×3, stride 2, pad 1, ReLU   (12×16×16)
    conv2: 12→32, 3×3, stride 2, pad 1, ReLU  (32×8×8)
    global average pool → features ∈ R³²
    head: linear 32→10 → logits
"""

import jax
import jax.numpy as jnp

IMG_C, IMG_H, IMG_W = 3, 32, 32
C1, C2, K = 12, 32, 3
FEATURE_DIM = C2
NUM_CLASSES = 10


def weight_shapes():
    """(name, shape) of the runtime weight inputs, in call order."""
    return [
        ("w1", (C1, IMG_C, K, K)),
        ("b1", (C1,)),
        ("w2", (C2, C1, K, K)),
        ("b2", (C2,)),
        ("wh", (NUM_CLASSES, FEATURE_DIM)),
        ("bh", (NUM_CLASSES,)),
    ]


def _conv(x, w, b, stride):
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=((1, 1), (1, 1)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return y + b[None, :, None, None]


def features(imgs, w1, b1, w2, b2, wh, bh):
    """imgs [N,3,32,32] → (features [N,32], logits [N,10])."""
    h = jnp.maximum(_conv(imgs, w1, b1, 2), 0.0)
    h = jnp.maximum(_conv(h, w2, b2, 2), 0.0)
    feat = jnp.mean(h, axis=(2, 3))
    logits = feat @ wh.T + bh
    return feat, logits
