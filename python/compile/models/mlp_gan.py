"""Layer-2 JAX model: the MLP WGAN on 2-D mixtures.

Mirrors ``rust/src/model/mlp_gan.rs`` exactly (same architecture, same
losses, same parameter order), with the dense layers routed through the
Pallas matmul kernel so Layer 1 sits on the real training path.

The exported gradient function takes the *flat* parameter vector w = [θ;φ]
plus a noise batch and a data batch, and returns (F(w;ξ), L_G, L_D), where

    F(w) = [∂L_G/∂θ ; ∂L_D/∂φ],
    L_G  = −mean(D(G(z))),
    L_D  = −mean(D(x)) + mean(D(G(z))) + (λ/2)‖φ‖².
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..kernels.matmul import matmul

DATA_DIM = 2


@dataclass(frozen=True)
class MlpGanSpec:
    noise_dim: int = 4
    gen_hidden: int = 32
    disc_hidden: int = 32
    critic_l2: float = 1e-2

    # ---- flat layout (must match rust/src/model/mlp_gan.rs) ----
    def shapes(self):
        nz, hg, hd = self.noise_dim, self.gen_hidden, self.disc_hidden
        return [
            ("gen.w1", (hg, nz)),
            ("gen.b1", (hg,)),
            ("gen.w2", (DATA_DIM, hg)),
            ("gen.b2", (DATA_DIM,)),
            ("disc.w1", (hd, DATA_DIM)),
            ("disc.b1", (hd,)),
            ("disc.w2", (hd,)),
            ("disc.b2", (1,)),
        ]

    @property
    def dim(self):
        return sum(int(jnp.prod(jnp.array(s))) for _, s in self.shapes())

    @property
    def theta_dim(self):
        """Length of the generator block (θ comes first)."""
        nz, hg = self.noise_dim, self.gen_hidden
        return hg * nz + hg + DATA_DIM * hg + DATA_DIM

    def unflatten(self, w):
        out = {}
        off = 0
        for name, shape in self.shapes():
            n = 1
            for s in shape:
                n *= s
            out[name] = w[off : off + n].reshape(shape)
            off += n
        return out


def generator(spec, params, z):
    """G(z) for a batch: z [B, nz] -> x [B, 2]. Uses the Pallas matmul."""
    h = jnp.tanh(matmul(z, params["gen.w1"].T) + params["gen.b1"])
    return matmul(h, params["gen.w2"].T) + params["gen.b2"]


def critic(spec, params, x):
    """D(x) for a batch: x [B, 2] -> y [B]. Uses the Pallas matmul."""
    h = jnp.tanh(matmul(x, params["disc.w1"].T) + params["disc.b1"])
    return h @ params["disc.w2"] + params["disc.b2"][0]


def losses(spec, w, z, x_real):
    """(L_G, L_D) on a fixed minibatch (z [B,nz], x_real [B,2])."""
    p = spec.unflatten(w)
    x_fake = generator(spec, p, z)
    y_fake = critic(spec, p, x_fake)
    y_real = critic(spec, p, x_real)
    loss_g = -jnp.mean(y_fake)
    phi = w[spec.theta_dim :]
    loss_d = -jnp.mean(y_real) + jnp.mean(y_fake) + 0.5 * spec.critic_l2 * jnp.sum(
        phi * phi
    )
    return loss_g, loss_d


def gan_operator(spec, w, z, x_real):
    """F(w; ξ) = [∂L_G/∂θ ; ∂L_D/∂φ] plus the losses."""
    lg_fn = lambda w_: losses(spec, w_, z, x_real)[0]
    ld_fn = lambda w_: losses(spec, w_, z, x_real)[1]
    g_lg = jax.grad(lg_fn)(w)
    g_ld = jax.grad(ld_fn)(w)
    td = spec.theta_dim
    f = jnp.concatenate([g_lg[:td], g_ld[td:]])
    lg, ld = losses(spec, w, z, x_real)
    return f, lg, ld


def sample_generator(spec, w, z):
    """Generator forward for metric sampling: z [N,nz] -> x [N,2]."""
    return generator(spec, spec.unflatten(w), z)
