"""Layer-2 assembly: the exact jitted functions `aot.py` lowers to HLO.

Every entry in ``EXPORTS`` is one artifact: a pure function plus its
example input shapes and the metadata the Rust runtime needs to drive it
(flat dim, θ/φ split, batch size, padding). Keep this the single source
of truth — the Rust side reads it all from ``artifacts/manifest.json``.
"""

import jax.numpy as jnp

from .kernels.omd_update import omd_half_step
from .kernels.quantize import quantize_ef
from .models import dcgan, feature_net, mlp_gan

# ------------------------------------------------------------------ specs

MLP_SPEC = mlp_gan.MlpGanSpec()
DCGAN_SPEC = dcgan.DcganSpec()

MLP_BATCH = 32
DCGAN_BATCH = 16
MLP_SAMPLE_N = 256
DCGAN_SAMPLE_N = 64
FEATURE_BATCH = 64

QUANT_LEVELS = 127  # the paper's 8-bit setting (2^(8-1) - 1)
MLP_QBLOCK = 128
DCGAN_QBLOCK = 1024


def padded(n, block):
    return ((n + block - 1) // block) * block


MLP_PAD = padded(MLP_SPEC.dim, MLP_QBLOCK)
DCGAN_PAD = padded(DCGAN_SPEC.dim, DCGAN_QBLOCK)


# ------------------------------------------------------------- functions


def mlp_gan_grad(w, z, x):
    """(w[dim], z[B,nz], x[B,2]) → (F[dim], loss_g[], loss_d[])."""
    return mlp_gan.gan_operator(MLP_SPEC, w, z, x)


def mlp_gan_sample(w, z):
    return (mlp_gan.sample_generator(MLP_SPEC, w, z),)


def dcgan_grad(w, z, x):
    return dcgan.gan_operator(DCGAN_SPEC, w, z, x)


def dcgan_sample(w, z):
    return (dcgan.sample_generator(DCGAN_SPEC, w, z),)


def quantize_ef_mlp(p, u):
    return quantize_ef(p, u, levels=QUANT_LEVELS, block=MLP_QBLOCK)


def quantize_ef_dcgan(p, u):
    return quantize_ef(p, u, levels=QUANT_LEVELS, block=DCGAN_QBLOCK)


def omd_half_mlp(w, f_prev, e, eta):
    return (omd_half_step(w, f_prev, e, eta, block=MLP_QBLOCK),)


def omd_half_dcgan(w, f_prev, e, eta):
    return (omd_half_step(w, f_prev, e, eta, block=DCGAN_QBLOCK),)


def feature_net_score(w1, b1, w2, b2, wh, bh, imgs):
    return feature_net.features(imgs, w1, b1, w2, b2, wh, bh)


# ------------------------------------------------------------------ table

F32 = jnp.float32


def _s(*dims):
    return jnp.zeros(dims, F32)


EXPORTS = {
    "mlp_gan_grad": {
        "fn": mlp_gan_grad,
        "example": (
            _s(MLP_SPEC.dim),
            _s(MLP_BATCH, MLP_SPEC.noise_dim),
            _s(MLP_BATCH, 2),
        ),
        "meta": {
            "model": "mlp_gan",
            "dim": MLP_SPEC.dim,
            "theta_dim": MLP_SPEC.theta_dim,
            "batch": MLP_BATCH,
            "noise_dim": MLP_SPEC.noise_dim,
            "data_shape": [2],
        },
    },
    "mlp_gan_sample": {
        "fn": mlp_gan_sample,
        "example": (_s(MLP_SPEC.dim), _s(MLP_SAMPLE_N, MLP_SPEC.noise_dim)),
        "meta": {
            "model": "mlp_gan",
            "dim": MLP_SPEC.dim,
            "sample_n": MLP_SAMPLE_N,
            "noise_dim": MLP_SPEC.noise_dim,
        },
    },
    "dcgan_grad": {
        "fn": dcgan_grad,
        "example": (
            _s(DCGAN_SPEC.dim),
            _s(DCGAN_BATCH, DCGAN_SPEC.noise_dim),
            _s(DCGAN_BATCH, 3, 32, 32),
        ),
        "meta": {
            "model": "dcgan",
            "dim": DCGAN_SPEC.dim,
            "theta_dim": DCGAN_SPEC.theta_dim,
            "batch": DCGAN_BATCH,
            "noise_dim": DCGAN_SPEC.noise_dim,
            "data_shape": [3, 32, 32],
        },
    },
    "dcgan_sample": {
        "fn": dcgan_sample,
        "example": (_s(DCGAN_SPEC.dim), _s(DCGAN_SAMPLE_N, DCGAN_SPEC.noise_dim)),
        "meta": {
            "model": "dcgan",
            "dim": DCGAN_SPEC.dim,
            "sample_n": DCGAN_SAMPLE_N,
            "noise_dim": DCGAN_SPEC.noise_dim,
        },
    },
    "quantize_ef_mlp": {
        "fn": quantize_ef_mlp,
        "example": (_s(MLP_PAD), _s(MLP_PAD)),
        "meta": {
            "model": "mlp_gan",
            "padded_dim": MLP_PAD,
            "dim": MLP_SPEC.dim,
            "levels": QUANT_LEVELS,
            "block": MLP_QBLOCK,
        },
    },
    "quantize_ef_dcgan": {
        "fn": quantize_ef_dcgan,
        "example": (_s(DCGAN_PAD), _s(DCGAN_PAD)),
        "meta": {
            "model": "dcgan",
            "padded_dim": DCGAN_PAD,
            "dim": DCGAN_SPEC.dim,
            "levels": QUANT_LEVELS,
            "block": DCGAN_QBLOCK,
        },
    },
    "omd_half_mlp": {
        "fn": omd_half_mlp,
        "example": (_s(MLP_PAD), _s(MLP_PAD), _s(MLP_PAD), _s()),
        "meta": {
            "model": "mlp_gan",
            "padded_dim": MLP_PAD,
            "dim": MLP_SPEC.dim,
            "block": MLP_QBLOCK,
        },
    },
    "omd_half_dcgan": {
        "fn": omd_half_dcgan,
        "example": (_s(DCGAN_PAD), _s(DCGAN_PAD), _s(DCGAN_PAD), _s()),
        "meta": {
            "model": "dcgan",
            "padded_dim": DCGAN_PAD,
            "dim": DCGAN_SPEC.dim,
            "block": DCGAN_QBLOCK,
        },
    },
    "feature_net": {
        "fn": feature_net_score,
        "example": tuple(
            [_s(*shape) for _, shape in feature_net.weight_shapes()]
            + [_s(FEATURE_BATCH, 3, 32, 32)]
        ),
        "meta": {
            "batch": FEATURE_BATCH,
            "feature_dim": feature_net.FEATURE_DIM,
            "num_classes": feature_net.NUM_CLASSES,
        },
    },
}
