"""Layer-1 Pallas kernel: fused OMD half-step (Algorithm 2 line 4).

    w_half = w - (eta * f_prev + e)

One pass over HBM instead of three (scale, add, subtract) — the classic
AXPY-fusion win. Grid = 1-D blocks of the flat parameter vector.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK = 2048


def _omd_kernel(w_ref, f_ref, e_ref, eta_ref, o_ref):
    eta = eta_ref[0]
    o_ref[...] = w_ref[...] - (eta * f_ref[...] + e_ref[...])


@functools.partial(jax.jit, static_argnames=("block",))
def omd_half_step(w, f_prev, e, eta, block=DEFAULT_BLOCK):
    """Fused ``w - (eta*f_prev + e)`` over 1-D f32 vectors.

    ``n`` must be a multiple of ``block`` (aot.py pads model sizes).
    ``eta`` is a scalar (traced, so one artifact serves every step size).
    """
    assert w.ndim == 1 and w.shape == f_prev.shape == e.shape
    n = w.shape[0]
    assert n % block == 0, f"n={n} must be a multiple of block={block}"
    n_blocks = n // block
    eta_arr = jnp.asarray(eta, jnp.float32).reshape(1)
    out = pl.pallas_call(
        _omd_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            # eta: same scalar block for every grid step
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, block), jnp.float32),
        interpret=True,
    )(
        w.reshape(n_blocks, block),
        f_prev.reshape(n_blocks, block),
        e.reshape(n_blocks, block),
        eta_arr,
    )
    return out.reshape(n)


def vmem_bytes(block=DEFAULT_BLOCK):
    """VMEM residency per grid step: w, f, e in + out, f32."""
    return 4 * 4 * block
