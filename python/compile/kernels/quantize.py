"""Layer-1 Pallas kernel: fused blockwise quantize + error feedback.

Implements Algorithm 2 lines 6-8 in one pass over the gradient vector:

    p     (input)  = eta * F + e_prev   (computed upstream)
    q     (output) = Q(p)    -- blockwise ||.||_inf stochastic quantization
    e     (output) = p - q   -- the new error memory

TPU mapping (DESIGN.md §6 Hardware-Adaptation): the paper's GPU kernels do
a per-threadblock max-reduce then a per-element stochastic round; here the
1-D gradient is viewed as (n_blocks, block) rows, one row per grid step,
sized so a row fits VMEM (block = 1024 f32 = 4 KiB/input; three resident
buffers + uniforms ~ 16 KiB/step). The max-reduce happens in-register on
the VPU; stochastic rounding consumes pre-generated uniforms (interpret
mode has no on-core PRNG) fed as a second input stream.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK = 1024


def _quantize_ef_kernel(p_ref, u_ref, q_ref, e_ref, *, levels):
    p = p_ref[...]
    u = u_ref[...]
    s = jnp.float32(levels)
    scale = jnp.max(jnp.abs(p))
    safe = jnp.where(scale > 0.0, scale, 1.0)
    grid = jnp.minimum(jnp.abs(p) / safe, 1.0) * s
    lo = jnp.floor(grid)
    frac = grid - lo
    level = jnp.where(u < frac, lo + 1.0, lo)
    q = jnp.sign(p) * safe * (level / s)
    q = jnp.where(scale > 0.0, q, jnp.zeros_like(q))
    q_ref[...] = q
    e_ref[...] = p - q


@functools.partial(jax.jit, static_argnames=("levels", "block"))
def quantize_ef(p, u, levels=127, block=DEFAULT_BLOCK):
    """Fused quantize + error-feedback over a 1-D vector.

    Args:
      p: f32[n] with n a multiple of ``block`` (pad upstream; `aot.py`
         exports per-model sizes already padded).
      u: f32[n] uniforms in [0, 1) driving the stochastic rounding.
      levels: quantization levels s (127 = the paper's 8-bit setting).
      block: elements per scale block (one grid step each).

    Returns:
      (q, e): the quantized vector and the new error memory.
    """
    assert p.ndim == 1 and p.shape == u.shape
    n = p.shape[0]
    assert n % block == 0, f"n={n} must be a multiple of block={block}"
    n_blocks = n // block
    p2 = p.reshape(n_blocks, block)
    u2 = u.reshape(n_blocks, block)
    q2, e2 = pl.pallas_call(
        functools.partial(_quantize_ef_kernel, levels=levels),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, block), jnp.float32),
            jax.ShapeDtypeStruct((n_blocks, block), jnp.float32),
        ],
        interpret=True,
    )(p2, u2)
    return q2.reshape(n), e2.reshape(n)


def vmem_bytes(block=DEFAULT_BLOCK):
    """VMEM residency per grid step: p, u in + q, e out, f32."""
    return 4 * 4 * block
