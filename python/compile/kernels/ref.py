"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth: ``python/tests/`` asserts the
Pallas kernels (run under ``interpret=True``) match these references to
float tolerance across a hypothesis-driven sweep of shapes and inputs.
"""

import jax.numpy as jnp


def matmul_ref(x, y):
    """Plain matmul with f32 accumulation."""
    return jnp.matmul(
        x.astype(jnp.float32), y.astype(jnp.float32), precision="highest"
    )


def quantize_ef_ref(p, u, levels, block):
    """Blockwise ||.||_inf stochastic quantization with error feedback.

    The reference for ``kernels.quantize.quantize_ef``:

    - split ``p`` (1-D, length a multiple of ``block``) into blocks;
    - per-block scale = max |p_i| (0-safe);
    - stochastic rounding of |p|/scale * levels using uniforms ``u``;
    - q = sign(p) * scale * level / levels;  e = p - q.

    Returns ``(q, e)``.
    """
    n = p.shape[0]
    assert n % block == 0, f"{n} not a multiple of block {block}"
    pb = p.reshape(-1, block)
    ub = u.reshape(-1, block)
    scale = jnp.max(jnp.abs(pb), axis=1, keepdims=True)
    safe = jnp.where(scale > 0.0, scale, 1.0)
    s = jnp.float32(levels)
    grid = jnp.minimum(jnp.abs(pb) / safe, 1.0) * s
    lo = jnp.floor(grid)
    frac = grid - lo
    level = jnp.where(ub < frac, lo + 1.0, lo)
    q = jnp.sign(pb) * safe * (level / s)
    q = jnp.where(scale > 0.0, q, 0.0)
    e = pb - q
    return q.reshape(n), e.reshape(n)


def omd_update_ref(w, f_prev, e, eta):
    """Fused DQGAN half-step (Algorithm 2 line 4):

        w_half = w - (eta * f_prev + e)
    """
    return w - (eta * f_prev + e)
