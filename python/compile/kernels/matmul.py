"""Layer-1 Pallas kernel: VMEM-tiled matmul.

Used by the Layer-2 GAN's dense layers so the Pallas kernel sits on the
real training path of the exported HLO.

TPU mapping (DESIGN.md §6): the grid tiles C into (bm × bn) VMEM blocks
and streams bk-deep slabs of A and B through the MXU; the f32 accumulator
lives in the output block across the k-loop (revisiting grid dimension).
On this CPU testbed the kernel runs under ``interpret=True``, so the
BlockSpec structure (not wallclock) is what we optimize; the VMEM/MXU
estimates are recorded in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BM = 128
DEFAULT_BK = 128
DEFAULT_BN = 128


def _matmul_kernel(x_ref, y_ref, o_ref, *, n_k):
    """One (i, j, k) grid step: o[i,j] += x[i,k] @ y[k,j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )
    del n_k  # shape bookkeeping only


def _pad_to(a, m, axis):
    pad = (-a.shape[axis]) % m
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def _matmul_impl(x, y, bm=DEFAULT_BM, bk=DEFAULT_BK, bn=DEFAULT_BN):
    """``x @ y`` via the Pallas kernel (f32 accumulate), any 2-D shapes.

    Inputs are zero-padded up to the tile grid and the result is sliced
    back, so arbitrary (m, k) x (k, n) shapes are supported.
    """
    assert x.ndim == 2 and y.ndim == 2 and x.shape[1] == y.shape[0]
    m, k = x.shape
    _, n = y.shape
    # Shrink tiles for small operands (keeps the grid non-degenerate).
    bm_, bk_, bn_ = (min(bm, max(m, 8)), min(bk, max(k, 8)), min(bn, max(n, 8)))
    xp = _pad_to(_pad_to(x.astype(jnp.float32), bm_, 0), bk_, 1)
    yp = _pad_to(_pad_to(y.astype(jnp.float32), bk_, 0), bn_, 1)
    mp, kp = xp.shape
    _, np_ = yp.shape
    grid = (mp // bm_, np_ // bn_, kp // bk_)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU-PJRT execution (see /opt/xla-example/README)
    )(xp, yp)
    return out[:m, :n]


@jax.custom_vjp
def matmul(x, y):
    """Differentiable Pallas matmul (default tiles).

    The VJP runs the same Pallas kernel on the cotangent:
      dX = dC @ Yᵀ,  dY = Xᵀ @ dC
    so the kernel is on both the forward and backward training paths.
    """
    return _matmul_impl(x, y)


def _matmul_fwd(x, y):
    return _matmul_impl(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    return _matmul_impl(g, y.T), _matmul_impl(x.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def vmem_bytes(bm=DEFAULT_BM, bk=DEFAULT_BK, bn=DEFAULT_BN):
    """Estimated VMEM residency per grid step (f32): x + y + o blocks."""
    return 4 * (bm * bk + bk * bn + bm * bn)


def mxu_utilization_estimate(m, k, n, bm=DEFAULT_BM, bk=DEFAULT_BK, bn=DEFAULT_BN):
    """Fraction of MXU-issued MACs that are useful (non-padding)."""
    mp = -(-m // bm) * bm
    kp = -(-k // bk) * bk
    np_ = -(-n // bn) * bn
    return (m * k * n) / (mp * kp * np_)
